"""The all-device query pipeline (planner/device.py + fused kernels).

The contract under test: with a device backend, one staged query batch
runs probe → block decode → K∩ scatter → estimator → output head (packed
threshold words or top-k) as ONE device program — no host transfer
between staging and the packed fetch — while bit-matching the dense
sweep. Plus the machinery that keeps steady-state serving on one
compiled program: Gq/k shape bucketing with inert padding, the pooled
staging buffers, the compile/staging counters, and the fused device
*build* (postings encoded on device, bit-identical to the host encoder).
"""

import logging

import jax
import numpy as np
import pytest

from repro import api, planner
from repro.core.arena import SketchArena
from repro.data.synth import generate_dataset, make_query_workload
from repro.planner import device as planner_device
from repro.planner import postings as postings_mod
from repro.planner.prune import f32_threshold

DEVICE_BACKENDS = ("jnp", "pallas")


@pytest.fixture(scope="module")
def corpus():
    recs = generate_dataset(m=120, n_elems=3000, alpha_freq=1.0,
                            alpha_size=1.6, seed=20)
    total = sum(len(r) for r in recs)
    queries = make_query_workload(recs, 4, seed=21)
    rng = np.random.default_rng(22)
    queries += [rng.choice(3000, size=s, replace=False) for s in (6, 40)]
    return recs, total, queries


def dense_corpus():
    """Records sharing near-ubiquitous small elements kept in the TAIL
    (tiny records + generous budget -> τ retains everything; r=2 keeps
    the buffer from swallowing them) so their posting lists span long
    runs of consecutive record ids -> dense bitmap blocks."""
    rng = np.random.default_rng(7)
    recs = []
    for _ in range(600):
        base = rng.choice(3000, size=rng.integers(2, 5), replace=False) + 100
        common = [c for c in range(10) if rng.random() < 0.85]
        recs.append(np.unique(np.concatenate([common, base]).astype(np.int64)))
    return recs


def build(engine, recs, budget, **kw):
    return api.get_engine(engine).build(recs, budget, **kw)


# ---------------------------------------------------------------------------
# transfer-guard residency: probe, decode, score, threshold pack, top-k
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_score_matrix_device_resident(corpus, backend):
    """``pruned_scores`` (probe + decode + estimator, no output head)
    stays on device under the transfer guard and equals the dense
    scores exactly."""
    recs, total, queries = corpus
    idx = build("gbkmv", recs, int(total * 0.1), backend=backend)
    dense = idx.batch_scores(queries)
    arena = idx._sketch_pack()
    m = arena.num_records
    qp, _, _, _ = idx._plan_queries(queries)
    dpost, dpack, sq = planner_device.stage_query_inputs(arena, qp)
    planner_device.pruned_scores(dpost, dpack, sq, m=m,
                                 backend=backend)  # warmup: compile
    dpost, dpack, sq = planner_device.stage_query_inputs(arena, qp)
    with jax.transfer_guard("disallow"):
        s = planner_device.pruned_scores(dpost, dpack, sq, m=m,
                                         backend=backend)
        assert not isinstance(s, np.ndarray)
    np.testing.assert_array_equal(np.asarray(s)[:, : len(queries)], dense)


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_vector_thresholds_device_resident(corpus, backend):
    """Per-query threshold vectors ride the same staged f32-exact cut:
    no transfer inside the guard, hits equal per-query dense calls."""
    recs, total, queries = corpus
    idx = build("gbkmv", recs, int(total * 0.1), backend=backend)
    thr = np.linspace(0.2, 0.9, len(queries))
    want = [idx.batch_query([q], float(t), plan="dense")[0]
            for q, t in zip(queries, thr)]
    arena = idx._sketch_pack()
    m = arena.num_records
    qp, _, _, _ = idx._plan_queries(queries)
    dpost, dpack, sq = planner_device.stage_query_inputs(arena, qp, thr)
    planner_device.fused_mask_words(dpost, dpack, sq, m=m,
                                    backend=backend)  # warmup: compile
    dpost, dpack, sq = planner_device.stage_query_inputs(arena, qp, thr)
    with jax.transfer_guard("disallow"):
        words = planner_device.fused_mask_words(
            dpost, dpack, sq, m=m, backend=backend)
        assert not isinstance(words, np.ndarray)
    mask = planner_device.unpack_hit_words(words, m)[:, : len(queries)]
    got = planner.prune.mask_to_hits(mask)
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_packed_words_encode_scores_exactly(corpus, backend):
    """The packed hit words are literally ``score >= fl32(t)`` — decode
    them against the device score matrix bit for bit."""
    recs, total, queries = corpus
    idx = build("gbkmv", recs, int(total * 0.1), backend=backend)
    arena = idx._sketch_pack()
    m = arena.num_records
    qp, _, _, _ = idx._plan_queries(queries)
    t = 0.4
    dpost, dpack, sq = planner_device.stage_query_inputs(arena, qp, t)
    words = planner_device.fused_mask_words(dpost, dpack, sq,
                                            m=m, backend=backend)
    mask = planner_device.unpack_hit_words(words, m)
    dpost, dpack, sq = planner_device.stage_query_inputs(arena, qp)
    s = np.asarray(planner_device.pruned_scores(dpost, dpack, sq, m=m,
                                                backend=backend))
    np.testing.assert_array_equal(mask, s >= f32_threshold(t))


# ---------------------------------------------------------------------------
# shape bucketing + staging pool: one compiled program in steady state
# ---------------------------------------------------------------------------


def test_compile_cache_and_staging_reuse(corpus, caplog):
    """Batches of 2/5/8 queries share one Gq bucket: one compile
    signature, one staging allocation, the rest cache hits + pool reuse.
    A 9-query batch crosses the bucket and logs the slow-path line."""
    recs, total, queries = corpus
    idx = build("gbkmv", recs, int(total * 0.1), backend="jnp")
    qs = (queries * 2)[:9]
    planner_device.reset_pipeline_stats()
    for n in (2, 5, 8):
        idx.batch_query(qs[:n], 0.5, plan="pruned")
    st = planner_device.pipeline_stats()
    assert st["calls"] == 3
    assert st["compiles"] == 1 and st["cache_hits"] == 2
    assert st["staging_alloc"] == 1 and st["staging_reuse"] == 2
    assert st["signatures"] == 1 and st["staging_buffers"] == 1
    with caplog.at_level(logging.INFO, logger="repro.planner.device"):
        idx.batch_query(qs[:9], 0.5, plan="pruned")   # new Gq bucket (16)
    st = planner_device.pipeline_stats()
    assert st["compiles"] == 2 and st["staging_buffers"] == 2
    assert any("slow path" in r.message for r in caplog.records)


def test_gq_bucket_padding_is_inert(corpus):
    """Every batch size across a bucket (1..9 queries) returns exactly
    the per-query dense answers — the PAD-query padding never leaks into
    real columns, for threshold hits and for top-k."""
    recs, total, queries = corpus
    idx = build("gbkmv", recs, int(total * 0.1), backend="jnp")
    dense = build("gbkmv", recs, int(total * 0.1), backend="numpy")
    qs = (queries * 2)[:9]
    want = [dense.batch_query([q], 0.5, plan="dense")[0] for q in qs]
    wtop = [dense.topk(q, 7, plan="dense") for q in qs]
    for n in range(1, 10):
        got = idx.batch_query(qs[:n], 0.5, plan="pruned")
        assert len(got) == n
        for w, g in zip(want[:n], got):
            np.testing.assert_array_equal(w, g)
    for q, (wi, ws) in zip(qs, wtop):
        gi, gs = idx.topk(q, 7, plan="pruned")
        np.testing.assert_array_equal(gi, wi)
        np.testing.assert_array_equal(gs, ws)


# ---------------------------------------------------------------------------
# device top-k: host pruned_topk contract, engines × backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
@pytest.mark.parametrize("engine", ("gbkmv", "gkmv"))
def test_topk_matches_host_pruned_topk(corpus, engine, backend):
    """``pruned_topk_device`` == host ``planner.pruned_topk`` (same
    (score desc, id asc) order, same shortfall fill) including k > m."""
    recs, total, queries = corpus
    idx = build(engine, recs, int(total * 0.1), backend=backend)
    arena = idx._sketch_pack()
    qp, _, _, _ = idx._plan_queries(queries)
    for k in (1, 9, 2 * len(recs)):
        got = planner_device.pruned_topk_device(
            arena, qp, k, backend=backend)
        for g, (ids, vals) in enumerate(got):
            # single-query pack: pruned_topk's score_fn addresses query 0
            qp_g, hr, br, sz = idx._plan_queries([queries[g]])
            want_ids, want_vals = planner.pruned_topk(
                idx._postings(), hr[0], br[0], int(sz[0]),
                k, idx._pair_score_fn(qp_g), arena.num_records)
            np.testing.assert_array_equal(ids, want_ids)
            np.testing.assert_array_equal(vals, want_vals)


def test_topk_kmv_host_route_still_matches(corpus):
    """kmv has no device twin — plan="pruned" takes the host route and
    must still match the dense ordering."""
    recs, total, queries = corpus
    idx = build("kmv", recs, int(total * 0.1), backend="jnp")
    for k in (3, 17):
        pi, ps = idx.topk(queries[0], k, plan="pruned")
        di, ds = idx.topk(queries[0], k, plan="dense")
        np.testing.assert_array_equal(pi, di)
        np.testing.assert_array_equal(ps, ds)


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_topk_tie_break_and_shortfall(backend):
    """12 identical records tie at the top: ids come back ascending.
    With k past the candidates, zero-score records fill in ascending-id
    order — the dense (-score, id) rule end to end."""
    recs = [np.arange(50)] * 12 + \
        [np.arange(1000 + 10 * i, 1000 + 10 * i + 5) for i in range(8)]
    idx = build("gbkmv", recs, 600, backend=backend)
    q = np.arange(25)
    ids, vals = idx.topk(q, 12, plan="pruned")
    np.testing.assert_array_equal(ids, np.arange(12))
    assert len(set(vals.tolist())) == 1
    ids, vals = idx.topk(q, 18, plan="pruned")
    di, dv = idx.topk(q, 18, plan="dense")
    np.testing.assert_array_equal(ids, di)
    np.testing.assert_array_equal(vals, dv)
    # shortfall tail is the ascending zero-score ids
    np.testing.assert_array_equal(ids[12:], np.sort(ids[12:]))


def test_f32_slack_bound_on_device():
    """The float32-rounding edge (buffer-only score fl32(1/3) > 1/3)
    that motivated the host bound slack: the device path thresholds in
    float32 exactly, so the dense hit survives."""
    recs = [np.asarray([0, 100 + i, 200 + i, 300 + i]) for i in range(20)]
    q = np.asarray([0, 9001, 9002])
    t = float(np.float32(1 / 3))
    dense = build("gbkmv", recs, 400, r=32, backend="numpy")
    want = dense.batch_query([q], t, plan="dense")[0]
    assert len(want) > 0                     # the edge actually triggers
    for backend in DEVICE_BACKENDS:
        idx = build("gbkmv", recs, 400, r=32, backend=backend)
        got = idx.batch_query([q], t, plan="pruned")[0]
        np.testing.assert_array_equal(want, got)


# ---------------------------------------------------------------------------
# fused device build: postings encoded on device, bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
@pytest.mark.parametrize("engine", ("gbkmv", "gkmv"))
def test_device_encode_bit_identity(corpus, engine, backend):
    """``build_backend=<device>`` encodes the tail postings on device;
    the installed store is bit-identical to the host encoder's (and the
    adopted mirror's has_dense flag agrees with the host meta bits) and
    queries match a host-built numpy twin."""
    recs, total, queries = corpus
    idx = build(engine, recs, int(total * 0.15), backend=backend,
                build_backend=backend, postings="eager")
    arena = idx._sketch_pack()
    assert arena._dev_post is not None       # adopted, not re-mirrored
    host_post = postings_mod.build_postings(arena)
    assert postings_mod.postings_equal(host_post, arena._post)
    assert arena._dev_post.has_dense == bool(
        np.any((host_post.tail.meta >> 13) & 1))
    dense = build(engine, recs, int(total * 0.15), backend="numpy")
    for w, g in zip(dense.batch_query(queries, 0.5, plan="dense"),
                    idx.batch_query(queries, 0.5, plan="pruned")):
        np.testing.assert_array_equal(w, g)


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_no_dense_blocks_compiles_dense_loop_out(backend):
    """Disjoint records -> every posting list is a single entry -> only
    sparse blocks. has_dense=False drops the dense decode loop from the
    compiled program; queries still match the dense sweep."""
    recs = [np.arange(20 * i, 20 * i + 15) for i in range(80)]
    idx = build("gbkmv", recs, 700, backend=backend,
                build_backend=backend, postings="eager")
    arena = idx._sketch_pack()
    assert not arena._dev_post.has_dense
    assert not np.any((arena._post.tail.meta >> 13) & 1)
    dense = build("gbkmv", recs, 700, backend="numpy")
    qs = [recs[3][:8], recs[40][:4], np.arange(5000, 5006)]
    for w, g in zip(dense.batch_query(qs, 0.5, plan="dense"),
                    idx.batch_query(qs, 0.5, plan="pruned")):
        np.testing.assert_array_equal(w, g)


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_device_encode_bit_identity_dense_blocks(backend):
    """Same bit-identity through the dense-bitmap encode path (mirror
    fields compared raw: keys/first/last/meta/off/payload)."""
    recs = dense_corpus()
    queries = [r[: max(2, len(r) // 2)] for r in recs[:4]]
    idx = build("gbkmv", recs, 20_000, r=2, backend=backend,
                build_backend=backend, postings="eager")
    arena = idx._sketch_pack()
    host_post = postings_mod.build_postings(arena)
    assert postings_mod.postings_equal(host_post, arena._post)
    dp, t = arena._dev_post, host_post.tail
    assert dp.has_dense and np.any((t.meta >> 13) & 1)
    np.testing.assert_array_equal(np.asarray(dp.keys), host_post.keys)
    np.testing.assert_array_equal(np.asarray(dp.first), t.first)
    np.testing.assert_array_equal(np.asarray(dp.last), t.last)
    np.testing.assert_array_equal(np.asarray(dp.meta), t.meta)
    np.testing.assert_array_equal(np.asarray(dp.off), t.off.astype(np.int32))
    np.testing.assert_array_equal(np.asarray(dp.payload), t.payload)
    dense = build("gbkmv", recs, 20_000, r=2, backend="numpy")
    for w, g in zip(dense.batch_query(queries, 0.5, plan="dense"),
                    idx.batch_query(queries, 0.5, plan="pruned")):
        np.testing.assert_array_equal(w, g)
    wi, ws = dense.topk(queries[0], 9, plan="dense")
    gi, gs = idx.topk(queries[0], 9, plan="pruned")
    np.testing.assert_array_equal(gi, wi)
    np.testing.assert_array_equal(gs, ws)


# ---------------------------------------------------------------------------
# sharded single-device route
# ---------------------------------------------------------------------------


def test_sharded_single_device_takes_fused_route(corpus):
    """A 1-device ShardedIndex serves pruned batches and top-k through
    the fused pipeline (no host candidate sets) with dense parity."""
    from jax.sharding import Mesh

    from repro.sketchindex.distributed import ShardedIndex

    recs, total, queries = corpus
    host = build("gbkmv", recs, int(total * 0.1), backend="jnp")
    dense = build("gbkmv", recs, int(total * 0.1), backend="numpy")
    mesh = Mesh(np.array(jax.devices()[:1]), ("records",))
    sh = ShardedIndex(host, mesh, backend="jnp")
    assert sh._device_route()
    got = sh.batch_query(queries, 0.6, plan="pruned")
    want = dense.batch_query(queries, 0.6, plan="dense")
    for w, g in zip(want, got):
        np.testing.assert_array_equal(w, g)
    assert sh.last_plan.path == "pruned"
    assert sh.last_candidates is None        # nothing materialized on host
    out = sh.serve_batch(queries, 0.6, k=7, plan="pruned", explain=True)
    for q, res in zip(queries, out):
        wi, ws = dense.topk(q, 7, plan="dense")
        np.testing.assert_array_equal(res["topk_ids"], wi)
        np.testing.assert_array_equal(res["topk_scores"], ws)
        assert res["explain"]["plan"] == "pruned"
