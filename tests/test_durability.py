"""Durability-layer tests: WAL unit behavior, atomic snapshots, the
chaos kill-at-every-fault-point matrix (recovered index must answer
bit-identical to a never-crashed reference with zero acknowledged
ingests lost), read-only degradation over live HTTP, idempotency-key
dedupe, atomic ``WindowManager.save``, ``CorruptIndexError``, and the
client's idempotent-retry/backoff contract."""

import json
import math
import os
import time

import numpy as np
import pytest

from repro import api
from repro.ft import chaos
from repro.service import (
    AsyncSketchServer, Durability, ReadOnly, ServiceApp, ServiceClient,
    ServiceError, ServiceHandle, WriteAheadLog, parse_prometheus)
from repro.service.wal import (
    IdempotencyCache, WalCorruption, decode_segment, encode_entry)

BUDGET = 1500


def make_records(seed, n, universe=500, lo=5, hi=30):
    rng = np.random.default_rng(seed)
    return [rng.choice(universe, size=int(rng.integers(lo, hi)),
                       replace=False) for _ in range(n)]


def build_wm(base):
    """The deterministic 'dataset build' both the crashed and the
    reference timelines start from."""
    return api.build("gbkmv", base, BUDGET, backend="numpy",
                     windowed=True, epoch=0)


class StubIndex:
    """Minimal serve_batch/insert protocol (mirrors test_service.py's
    stub) so HTTP-layer durability behavior is testable without jax."""

    def __init__(self):
        self.records = [np.arange(5)]

    @property
    def num_records(self):
        return len(self.records)

    def serve_batch(self, queries, thresholds, k, plan="auto"):
        thresholds = np.broadcast_to(np.asarray(thresholds), (len(queries),))
        out = []
        for q, t in zip(queries, thresholds):
            hits = (np.asarray([], np.int64) if math.isinf(t)
                    else np.asarray(sorted(np.asarray(q).tolist())[:2]))
            out.append({"hits": hits,
                        "topk_ids": np.arange(k, dtype=np.int64),
                        "topk_scores": np.linspace(1.0, 0.5, max(k, 1),
                                                   dtype=np.float32)})
        return out

    def insert(self, records):
        self.records.extend(records)

    def save(self, path):
        np.savez(path, n=self.num_records)


# -- WAL unit behavior -------------------------------------------------------


def test_wal_append_reopen_and_replay(tmp_path):
    w = WriteAheadLog(str(tmp_path), fsync="batch")
    assert w.last_seq == 0
    w.append({"kind": "ingest", "records": [[1, 2]], "epoch": 0,
              "idem": None})
    w.append({"kind": "retire", "before": 3})
    w.sync()
    assert w.fsyncs_total == 1          # group commit: one fsync, two appends
    w.close()
    # Reopen continues the sequence in the same segment.
    w2 = WriteAheadLog(str(tmp_path))
    assert w2.last_seq == 2
    w2.append({"kind": "ingest", "records": [[7]], "epoch": 1, "idem": "k"})
    w2.sync()
    entries = list(w2.entries())
    assert [e["seq"] for e in entries] == [1, 2, 3]
    assert [e["kind"] for e in entries] == ["ingest", "retire", "ingest"]
    assert list(w2.entries(after_seq=2))[0]["idem"] == "k"
    w2.close()


def test_wal_fsync_policies(tmp_path):
    w = WriteAheadLog(str(tmp_path / "always"), fsync="always")
    w.append({"kind": "retire", "before": 1})
    w.append({"kind": "retire", "before": 2})
    assert w.fsyncs_total == 2          # one per append
    w.close()
    w = WriteAheadLog(str(tmp_path / "off"), fsync="off")
    w.append({"kind": "retire", "before": 1})
    w.sync()
    assert w.fsyncs_total == 0          # page cache only
    w.close()
    with pytest.raises(ValueError, match="fsync policy"):
        WriteAheadLog(str(tmp_path / "bad"), fsync="sometimes")


def test_wal_torn_tail_tolerated_only_on_newest_segment(tmp_path):
    w = WriteAheadLog(str(tmp_path), fsync="batch")
    for i in range(3):
        w.append({"kind": "retire", "before": i})
    w.sync()
    seg = w._segments[-1][0]
    w.close()
    # A torn final frame (half a record) is truncated on reopen.
    with open(seg, "ab", buffering=0) as f:
        f.write(encode_entry({"kind": "retire", "before": 9, "seq": 4})[:11])
    w2 = WriteAheadLog(str(tmp_path))
    assert w2.torn_tail_bytes > 0
    assert [e["seq"] for e in w2.entries()] == [1, 2, 3]
    # ...and appending after the truncate yields a clean decodable log.
    w2.append({"kind": "retire", "before": 9})
    w2.sync()
    w2.close()
    w3 = WriteAheadLog(str(tmp_path))
    assert [e["seq"] for e in w3.entries()] == [1, 2, 3, 4]
    assert w3.torn_tail_bytes == 0
    w3.close()


def test_wal_mid_log_corruption_refuses(tmp_path):
    w = WriteAheadLog(str(tmp_path), fsync="batch")
    w.append({"kind": "retire", "before": 1})
    w.sync()
    w.rotate()                          # seals segment 1, opens segment 2
    w.append({"kind": "retire", "before": 2})
    w.sync()
    first_seg = w._segments[0][0]
    w.close()
    with open(first_seg, "r+b") as f:   # flip a payload byte: CRC breaks
        f.seek(12)
        b = f.read(1)
        f.seek(12)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(WalCorruption, match="newest segment"):
        WriteAheadLog(str(tmp_path))


def test_wal_rotate_and_truncate_through(tmp_path):
    w = WriteAheadLog(str(tmp_path), fsync="batch")
    w.append({"kind": "retire", "before": 1})
    w.rotate()
    w.append({"kind": "retire", "before": 2})
    w.rotate()
    w.append({"kind": "retire", "before": 3})
    w.sync()
    assert w.segment_count == 3
    dropped = w.truncate_through(2)     # first two segments fully covered
    assert dropped == 2 and w.segment_count == 1
    assert [e["seq"] for e in w.entries()] == [3]
    w.close()


def test_wal_segment_size_rotation(tmp_path):
    w = WriteAheadLog(str(tmp_path), fsync="off", segment_bytes=64)
    for i in range(6):
        w.append({"kind": "retire", "before": i})
    assert w.segment_count > 1          # size bound forced rotations
    assert [e["seq"] for e in w.entries()] == list(range(1, 7))
    w.close()


def test_idempotency_cache_bounded_lru():
    c = IdempotencyCache(capacity=2)
    c.put("a", {"ingested": 1})
    c.put("b", {"ingested": 2})
    assert c.get("a") == {"ingested": 1}    # touch: 'a' becomes MRU
    c.put("c", {"ingested": 3})             # evicts 'b'
    assert c.get("b") is None and c.get("c") == {"ingested": 3}
    c2 = IdempotencyCache(capacity=4)
    c2.load(c.export())
    assert c2.get("a") == {"ingested": 1} and len(c2) == 2


# -- chaos kill-and-recover matrix -------------------------------------------

# Every fault point from the harness, each as an in-process kill, plus a
# torn-write variant at the write-shaped point. The acceptance bar: the
# recovered index serves query/topk bit-identical to a never-crashed
# reference, and no acknowledged ingest is lost.
MATRIX = [(p, "crash") for p in chaos.FAULT_POINTS]
MATRIX.append(("wal.append.write", "torn"))


def _probe_parity(recovered, reference, queries):
    got = recovered.serve_batch(queries, 0.3, 5)
    want = reference.serve_batch(queries, 0.3, 5)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.sort(np.asarray(g["hits"])),
                                      np.sort(np.asarray(w["hits"])))
        np.testing.assert_array_equal(g["topk_ids"], w["topk_ids"])
        np.testing.assert_array_equal(np.asarray(g["topk_scores"]),
                                      np.asarray(w["topk_scores"]))


@pytest.mark.parametrize("point,action", MATRIX,
                         ids=[f"{p}-{a}" for p, a in MATRIX])
def test_kill_and_recover_bit_identical(point, action, tmp_path):
    base = make_records(0, 20)
    batch_a = make_records(1, 4)        # committed before the fault arms
    batch_b = make_records(2, 5)        # raced against the injected kill
    data_dir = str(tmp_path / "data")

    wm = build_wm(base)
    dur = Durability(data_dir, fsync="batch")
    srv = AsyncSketchServer(wm, durability=dur, max_batch=4, max_wait=0.001)
    acked = 0
    for r in batch_a:
        p = srv.submit_ingest([r], epoch=0)
        srv.step(force=True)
        assert p.done.is_set() and p.error is None
        acked += 1
    ps = srv.submit_snapshot()
    srv.step(force=True)
    assert ps.error is None and ps.result["wal_seq"] == len(batch_a)

    # Arm and run until the simulated kill unwinds out of the flush
    # loop. wal.append.* points fire on the first batch_b ingest; the
    # rotate/snapshot/truncate points fire during the closing snapshot.
    monkey = chaos.ChaosMonkey().arm(point, action)
    with chaos.installed(monkey):
        try:
            for r in batch_b:
                p = srv.submit_ingest([r], epoch=0)
                srv.step(force=True)
                if p.done.is_set() and p.error is None:
                    acked += 1
            p2 = srv.submit_snapshot()
            srv.step(force=True)
            if p2.error is None:
                pytest.fail(f"fault point {point} never fired")
        except chaos.SimulatedCrash as e:
            assert e.point == point
    assert monkey.hits == [point]

    # "Restart": fresh Durability over the same dir, exactly the launch
    # recovery flow — newest valid snapshot, else the deterministic
    # dataset build, then WAL-tail replay through normal ingest.
    dur2 = Durability(data_dir, fsync="batch")
    recovered, manifest = dur2.load_latest_index()
    if recovered is None:
        recovered = build_wm(base)
    stats = dur2.replay_into(recovered)
    assert stats["failed_entries"] == 0

    applied = recovered.num_records - len(base)
    assert applied >= acked, (
        f"{point}: {acked} ingests acknowledged but only {applied} "
        f"records survived recovery")

    # Never-crashed reference: same deterministic build, the same
    # surviving prefix applied through the same ingest path. (Durable
    # entries beyond the last ack may legitimately survive — the write
    # protocol promises acked ⊆ recovered ⊆ attempted, in order.)
    attempted = batch_a + batch_b
    assert applied <= len(attempted)
    reference = build_wm(base)
    for r in attempted[:applied]:
        reference.insert([r], epoch=0)
    queries = [base[0], base[7], batch_a[0], batch_b[0],
               make_records(9, 1)[0]]
    _probe_parity(recovered, reference, queries)


def test_second_recovery_is_idempotent(tmp_path):
    """Crashing after the snapshot rename but before WAL truncation must
    not double-apply the covered entries on the *next* boot either."""
    base = make_records(0, 10)
    data_dir = str(tmp_path / "data")
    wm = build_wm(base)
    dur = Durability(data_dir, fsync="batch")
    srv = AsyncSketchServer(wm, durability=dur, max_batch=4)
    extra = make_records(3, 3)
    for r in extra:
        srv.submit_ingest([r], epoch=0)
        srv.step(force=True)
    with chaos.installed(chaos.ChaosMonkey().arm("snapshot.post_rename")):
        srv.submit_snapshot()
        with pytest.raises(chaos.SimulatedCrash):
            srv.step(force=True)
    for boot in range(2):               # recover twice; both must agree
        d = Durability(data_dir)
        idx, _ = d.load_latest_index()
        assert idx is not None
        d.replay_into(idx)
        assert idx.num_records == len(base) + len(extra), f"boot {boot}"


def test_invalid_snapshot_skipped_for_older_valid_one(tmp_path):
    base = make_records(0, 10)
    data_dir = str(tmp_path / "data")
    wm = build_wm(base)
    dur = Durability(data_dir, fsync="batch")
    srv = AsyncSketchServer(wm, durability=dur, max_batch=4)
    srv.submit_snapshot()
    srv.step(force=True)
    srv.submit_ingest([make_records(5, 1)[0]], epoch=0)
    srv.step(force=True)
    srv.submit_snapshot()
    srv.step(force=True)
    snaps = sorted(os.listdir(dur.snap_dir))
    assert len(snaps) == 2
    # Bit-rot the newest snapshot's manifest: boot must fall back to the
    # older snapshot instead of refusing to serve at all.
    newest = os.path.join(dur.snap_dir, snaps[-1], "snap_manifest.json")
    with open(newest, "w") as f:
        f.write('{"version": 1, "wal_seq"')    # torn mid-write
    d2 = Durability(data_dir)
    idx, manifest = d2.load_latest_index()
    assert idx is not None and d2.invalid_snapshots_skipped == 1
    assert d2.snap_seq == 0 and manifest["wal_seq"] == 0
    assert idx.num_records == len(base)        # the older snapshot's state


# -- read-only degradation over live HTTP ------------------------------------


def test_disk_full_degrades_to_read_only(tmp_path):
    dur = Durability(str(tmp_path / "d"), fsync="batch")
    srv = AsyncSketchServer(StubIndex(), max_batch=4, max_wait=0.002,
                            durability=dur)
    monkey = chaos.ChaosMonkey().arm("wal.append.pre_write", "error",
                                     times=-1)
    with chaos.installed(monkey), ServiceHandle(ServiceApp(srv)) as h:
        cli = ServiceClient(*h.address)
        assert cli.readyz()["status"] == "ok"
        with pytest.raises(ServiceError) as ei:
            cli.ingest([[1, 2, 3]], stream=False)
        assert ei.value.status == 503          # mutation refused
        assert "read-only" in str(ei.value.body)
        # Queries keep answering from the in-memory index.
        np.testing.assert_array_equal(cli.query(np.arange(3), 0.5), [0, 1])
        # Liveness stays up; readiness flips; metrics reflect the state.
        hz = cli.healthz()
        assert hz["status"] == "ok" and hz["writable"] is False
        with pytest.raises(ServiceError) as ei:
            cli.readyz()
        assert ei.value.status == 503
        metrics = parse_prometheus(cli.metrics_text())
        assert metrics["service_read_only"] == 1
        # Sticky: later mutations fail fast at admission, even with the
        # fault no longer firing between calls.
        with pytest.raises(ServiceError) as ei:
            cli.ingest([[4, 5]], stream=False)
        assert ei.value.status == 503
        with pytest.raises(ServiceError) as ei:
            cli.snapshot()
        assert ei.value.status == 503
        cli.close()
    assert srv.read_only
    assert "injected IO error" in srv.read_only_reason


def test_fsync_failure_refuses_ack(tmp_path):
    """A group-commit fsync failure must NOT acknowledge the batch: not
    durable means not acked, and the server degrades to read-only."""
    dur = Durability(str(tmp_path / "d"), fsync="batch")
    srv = AsyncSketchServer(StubIndex(), durability=dur, max_batch=4)
    before = srv.index.num_records
    with chaos.installed(
            chaos.ChaosMonkey().arm("wal.append.pre_fsync", "error")):
        p = srv.submit_ingest([np.arange(4)])
        srv.step(force=True)
    assert isinstance(p.error, ReadOnly)
    assert srv.read_only
    assert srv.index.num_records == before     # never applied


def test_slow_io_delay_injection(tmp_path):
    dur = Durability(str(tmp_path / "d"), fsync="batch")
    srv = AsyncSketchServer(StubIndex(), durability=dur, max_batch=4)
    with chaos.installed(chaos.ChaosMonkey().arm(
            "wal.append.pre_fsync", "delay", delay_s=0.08)):
        t0 = time.monotonic()
        p = srv.submit_ingest([np.arange(3)])
        srv.step(force=True)
        elapsed = time.monotonic() - t0
    assert p.error is None and p.result == {"ingested": 1}
    assert elapsed >= 0.08                     # latency visible, not fatal


# -- idempotency keys --------------------------------------------------------


def test_server_level_idempotent_dedupe():
    srv = AsyncSketchServer(StubIndex(), max_batch=4)   # no data dir needed
    p1 = srv.submit_ingest([np.arange(3), np.arange(4)], idem="job-1")
    srv.step(force=True)
    assert p1.result == {"ingested": 2}
    n = srv.index.num_records
    p2 = srv.submit_ingest([np.arange(3), np.arange(4)], idem="job-1")
    srv.step(force=True)
    assert p2.result == {"ingested": 2, "deduped": True}
    assert srv.index.num_records == n          # nothing re-applied
    assert srv.deduped_total == 1
    # A different key applies normally.
    p3 = srv.submit_ingest([np.arange(5)], idem="job-2")
    srv.step(force=True)
    assert p3.result == {"ingested": 1} and srv.index.num_records == n + 1


def test_http_ingest_idempotency_key_roundtrip():
    srv = AsyncSketchServer(StubIndex(), max_batch=4, max_wait=0.002)
    with ServiceHandle(ServiceApp(srv, ingest_chunk=2)) as h:
        cli = ServiceClient(*h.address)
        recs = [np.arange(3), np.arange(4), np.arange(5)]
        out1 = cli.ingest(recs, idempotency_key="batch-7")
        assert out1 == {"ingested": 3, "chunks": 2, "deduped_chunks": 0}
        n = srv.index.num_records
        out2 = cli.ingest(recs, idempotency_key="batch-7")
        assert out2 == {"ingested": 3, "chunks": 2, "deduped_chunks": 2}
        assert srv.index.num_records == n      # full replay deduped
        # Unkeyed requests keep the exact legacy response shape.
        out3 = cli.ingest(recs)
        assert out3 == {"ingested": 3, "chunks": 2}
        assert srv.index.num_records == n + 3
        metrics = parse_prometheus(cli.metrics_text())
        assert metrics["service_ingest_deduped_total"] == 2
        cli.close()


def test_idempotency_window_survives_recovery(tmp_path):
    """Keys committed through the WAL dedupe again after a crash —
    the exactly-once contract a client retry relies on."""
    base = make_records(0, 8)
    data_dir = str(tmp_path / "data")
    wm = build_wm(base)
    dur = Durability(data_dir, fsync="batch")
    srv = AsyncSketchServer(wm, durability=dur, max_batch=4)
    rec = make_records(4, 1)[0]
    p = srv.submit_ingest([rec], epoch=0, idem="once")
    srv.step(force=True)
    assert p.result == {"ingested": 1}
    # Crash (no snapshot): recovery replays the WAL and rebuilds the
    # idempotency window from the entries' keys.
    dur2 = Durability(data_dir)
    recovered = build_wm(base)
    dur2.replay_into(recovered)
    srv2 = AsyncSketchServer(recovered, durability=dur2, max_batch=4)
    n = recovered.num_records
    p2 = srv2.submit_ingest([rec], epoch=0, idem="once")
    srv2.step(force=True)
    assert p2.result.get("deduped") is True
    assert recovered.num_records == n


# -- admin snapshot over HTTP ------------------------------------------------


def test_http_admin_snapshot_roundtrip(tmp_path):
    base = make_records(0, 12)
    wm = build_wm(base)
    dur = Durability(str(tmp_path / "d"), fsync="batch")
    srv = AsyncSketchServer(wm, durability=dur, max_batch=4, max_wait=0.002)
    with ServiceHandle(ServiceApp(srv, auth_token="s3cret")) as h:
        with pytest.raises(ServiceError) as ei:       # auth required
            ServiceClient(*h.address).snapshot()
        assert ei.value.status == 401
        cli = ServiceClient(*h.address, token="s3cret")
        cli.ingest([make_records(6, 1)[0]], epoch=0)
        out = cli.snapshot()
        assert out["fresh"] is True and out["wal_seq"] >= 1
        metrics = parse_prometheus(cli.metrics_text())
        assert metrics["snapshot_total"] == 1
        assert metrics["wal_appends_total"] >= 1
        assert metrics["snapshot_wal_seq"] == out["wal_seq"]
        cli.close()
    # The snapshot alone fully restores the served state.
    d2 = Durability(str(tmp_path / "d"))
    idx, manifest = d2.load_latest_index()
    stats = d2.replay_into(idx)
    assert stats["replayed_entries"] == 0      # WAL truncated by snapshot
    assert idx.num_records == wm.num_records


def test_http_admin_snapshot_without_data_dir_is_400():
    srv = AsyncSketchServer(StubIndex(), max_batch=4, max_wait=0.002)
    with ServiceHandle(ServiceApp(srv)) as h:
        cli = ServiceClient(*h.address)
        with pytest.raises(ServiceError) as ei:
            cli.snapshot()
        assert ei.value.status == 400
        assert "data dir" in str(ei.value.body)
        cli.close()


# -- atomic WindowManager.save -----------------------------------------------


def test_window_save_atomic_and_drops_stale_epochs(tmp_path):
    from repro.sketchindex.windows import WindowManager

    base = make_records(0, 10)
    wm = build_wm(base)
    wm.insert(make_records(1, 3), epoch=1)
    target = str(tmp_path / "win")
    wm.save(target)
    names = sorted(os.listdir(target))
    assert "epoch_00000000.npz" in names and "epoch_00000001.npz" in names
    assert not os.path.exists(target + ".tmp")
    assert not os.path.exists(target + ".old")
    # Retire epoch 0, save over the same dir: the stale epoch file from
    # the first save must not survive the swap.
    wm.retire(before=1)
    wm.save(target)
    names = sorted(os.listdir(target))
    assert "epoch_00000000.npz" not in names
    assert "epoch_00000001.npz" in names
    loaded = WindowManager.load(target)
    assert loaded.num_records == wm.num_records
    _probe_parity(loaded, wm, [base[0], make_records(8, 1)[0]])


def test_window_save_survives_stale_tmp_and_keeps_old_on_crash(tmp_path):
    base = make_records(0, 8)
    wm = build_wm(base)
    target = str(tmp_path / "win")
    # Garbage from a previously crashed save must not break the next one.
    os.makedirs(target + ".tmp")
    with open(os.path.join(target + ".tmp", "junk"), "w") as f:
        f.write("leftover")
    wm.save(target)
    assert not os.path.exists(target + ".tmp")
    with open(os.path.join(target, "window_manifest.json")) as f:
        assert json.load(f)["engine"] == "gbkmv"


# -- CorruptIndexError -------------------------------------------------------


def test_load_index_truncated_npz_raises_corrupt(tmp_path):
    base = make_records(0, 8)
    idx = api.build("gbkmv", base, BUDGET, backend="numpy")
    path = str(tmp_path / "idx.npz")
    idx.save(path)
    assert api.load_index(path).num_records == len(base)   # sanity
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size // 2)                              # torn download
    with pytest.raises(api.CorruptIndexError) as ei:
        api.load_index(path)
    assert path in str(ei.value)
    assert isinstance(ei.value, ValueError)    # old except-clauses still work


def test_load_index_wrong_file_and_missing_key(tmp_path):
    garbage = str(tmp_path / "not_an_index.npz")
    with open(garbage, "wb") as f:
        f.write(b"this is not a zip file at all")
    with pytest.raises(api.CorruptIndexError, match="not_an_index"):
        api.load_index(garbage)
    no_engine = str(tmp_path / "no_engine.npz")
    np.savez(no_engine, data=np.arange(3))
    with pytest.raises(api.CorruptIndexError, match="engine"):
        api.load_index(no_engine)
    with pytest.raises(FileNotFoundError):     # absence is NOT corruption
        api.load_index(str(tmp_path / "nope.npz"))


def test_load_index_payload_missing_arrays(tmp_path):
    path = str(tmp_path / "partial.npz")
    np.savez(path, engine="gbkmv")             # right header, no payload
    with pytest.raises(api.CorruptIndexError, match="partial"):
        api.load_index(path)


# -- client retry contract ---------------------------------------------------


class _FakeResp:
    def __init__(self, status, body=b"{}", headers=()):
        self.status, self._body, self._headers = status, body, headers

    def read(self):
        return self._body

    def getheaders(self):
        return list(self._headers)


class _ScriptedConn:
    """One scripted keep-alive connection: each element of ``script`` is
    an Exception to raise at request() or a _FakeResp to return."""

    def __init__(self, script):
        self.script = list(script)
        self.requests = []

    def request(self, method, path, body=None, headers=None,
                encode_chunked=False):
        if body is not None and not isinstance(body, (bytes, bytearray)):
            body = b"".join(body)      # force generator consumption
        self.requests.append((method, path, body))
        step = self.script[0]
        if isinstance(step, Exception):
            self.script.pop(0)
            raise step

    def getresponse(self):
        return self.script.pop(0)

    def close(self):
        pass


def _scripted_client(script, **kw):
    cli = ServiceClient("127.0.0.1", 1, **kw)
    conn = _ScriptedConn(script)
    cli._connection = lambda: conn
    return cli, conn


def test_client_does_not_replay_plain_post_on_stale_connection():
    # The server may have applied the POST before the socket died —
    # replaying it would double-ingest. The old client retried here.
    cli, conn = _scripted_client(
        [ConnectionResetError("stale"), _FakeResp(200)])
    with pytest.raises(ConnectionResetError):
        cli.request("POST", "/ingest", b"{}")
    assert len(conn.requests) == 1             # exactly one attempt


def test_client_replays_idempotent_requests_on_stale_connection():
    cli, conn = _scripted_client(
        [ConnectionResetError("stale"), _FakeResp(200, b'{"ok": 1}')])
    status, raw, _ = cli.request("GET", "/healthz")
    assert status == 200 and len(conn.requests) == 2
    # POST-shaped reads opt in explicitly (the /query path).
    cli2, conn2 = _scripted_client(
        [ConnectionResetError("stale"), _FakeResp(200, b'{"hits": [1]}')])
    np.testing.assert_array_equal(cli2.query(np.arange(2), 0.5), [1])
    assert len(conn2.requests) == 2


def test_client_backoff_honors_retry_after(monkeypatch):
    sleeps = []
    monkeypatch.setattr("repro.service.client.time.sleep", sleeps.append)
    cli, conn = _scripted_client(
        [_FakeResp(429, b'{"error": "busy"}', [("Retry-After", "0.2")]),
         _FakeResp(200, b'{"hits": []}')],
        retries=2, backoff_s=0.01, jitter=lambda: 0.0)
    cli.query(np.arange(2), 0.5)
    assert len(sleeps) == 1
    assert sleeps[0] >= 0.2                    # never shorter than the hint
    # Exhausted retries surface the 429 with its hint intact.
    cli2, _ = _scripted_client(
        [_FakeResp(429, b'{}', [("Retry-After", "0.5")])] * 3,
        retries=2, backoff_s=0.01, jitter=lambda: 0.0)
    with pytest.raises(ServiceError) as ei:
        cli2.query(np.arange(2), 0.5)
    assert ei.value.status == 429 and ei.value.retry_after == 0.5
    assert len(sleeps) == 3


def test_client_default_is_fail_fast():
    cli, _ = _scripted_client([_FakeResp(429, b'{}', [("Retry-After", "9")])])
    with pytest.raises(ServiceError) as ei:    # retries=0: no sleep, no loop
        cli.query(np.arange(2), 0.5)
    assert ei.value.status == 429


def test_client_keyed_ingest_retries_with_rebuilt_stream():
    # A keyed streamed ingest reconnects and REBUILDS the generator, so
    # the retry sends the full NDJSON body again from the start.
    cli, conn = _scripted_client(
        [ConnectionResetError("stale"),
         _FakeResp(200, b'{"ingested": 2, "chunks": 1, '
                        b'"deduped_chunks": 0}')],
        retries=1, backoff_s=0.0, jitter=lambda: 0.0)
    out = cli.ingest([np.arange(2), np.arange(3)], idempotency_key="k1")
    assert out["ingested"] == 2
    assert len(conn.requests) == 2
    assert conn.requests[0][2] == conn.requests[1][2] != b""
    # Without a key, the same drop propagates (no silent double-apply).
    cli2, conn2 = _scripted_client(
        [ConnectionResetError("stale"), _FakeResp(200)], retries=1)
    with pytest.raises(ConnectionResetError):
        cli2.ingest([np.arange(2)])
    assert len(conn2.requests) == 1
