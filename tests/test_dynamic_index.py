"""Dynamic-index tests: insertion equivalence vs full rebuild, τ
monotonicity under a fixed budget, budget enforcement."""

import numpy as np

from repro.core.exact import build_inverted, exact_search
from repro.core.gbkmv import build_gbkmv, search
from repro.core.search import f_score
from repro.data.synth import generate_dataset
from repro.sketchindex.dynamic import DynamicStats, insert_records, needs_rebuild


def _data(m, seed):
    return generate_dataset(m=m, n_elems=5000, alpha_freq=1.1,
                            alpha_size=2.0, seed=seed)


def test_insert_matches_rebuild_accuracy():
    """Incrementally built index ≈ from-scratch index in search quality."""
    recs = _data(300, 0)
    budget = 6000
    base = build_gbkmv(recs[:200], budget=budget, r=32)
    dyn, _ = insert_records(base, recs[200:], budget=budget)
    full = build_gbkmv(recs, budget=budget, r=32)
    assert dyn.num_records == full.num_records == 300

    exact_index = build_inverted(recs)
    f_dyn, f_full = [], []
    for q in recs[::40]:
        truth = exact_search(exact_index, q, 0.5)
        f_dyn.append(f_score(truth, search(dyn, q, 0.5)))
        f_full.append(f_score(truth, search(full, q, 0.5)))
    # Same budget, same data → comparable accuracy (τ may differ by the
    # buffer's different frequency snapshot).
    assert abs(np.mean(f_dyn) - np.mean(f_full)) < 0.15


def test_tau_only_decreases_and_budget_holds():
    recs = _data(400, 1)
    budget = 3000
    index = build_gbkmv(recs[:100], budget=budget, r=0)
    taus = [int(index.tau)]
    stats = DynamicStats()
    for lo in range(100, 400, 100):
        index, stats = insert_records(index, recs[lo:lo + 100],
                                      budget=budget, stats=stats)
        taus.append(int(index.tau))
        kept = int(np.asarray(index.sketches.lengths).sum())
        # τ is INCLUSIVE: every record containing the boundary element
        # keeps its (identical) hash, so ties overshoot by ≤ the boundary
        # element's frequency — bounded slack, never unbounded growth.
        assert kept <= budget + 100
    assert all(a >= b for a, b in zip(taus, taus[1:]))
    assert stats.tau_retightens >= 1
    assert stats.inserts == 300


def test_rows_remain_valid_tau_sketches():
    """Every row's kept hashes = ALL its hashes ≤ its threshold (Thm 2
    invariant preserved through incremental re-tightening)."""
    from repro.core.hashing import hash_u32_np

    recs = _data(150, 2)
    budget = 1500
    index = build_gbkmv(recs[:100], budget=budget, r=0)
    index, _ = insert_records(index, recs[100:], budget=budget)
    s = index.sketches
    for i, rec in enumerate(recs):
        h = np.sort(hash_u32_np(np.asarray(rec), seed=index.seed))
        thr = int(np.asarray(s.thresh)[i])
        expect = h[h <= thr]
        got = np.asarray(s.values)[i][: int(np.asarray(s.lengths)[i])]
        np.testing.assert_array_equal(got, expect)


def test_drift_triggers_rebuild_signal():
    rng = np.random.default_rng(3)
    recs = [np.unique(rng.integers(0, 100, 40)) for _ in range(50)]
    index = build_gbkmv(recs, budget=800, r=32)
    # New data from a disjoint element universe → buffer useless → drift.
    new = [np.unique(rng.integers(10_000, 20_000, 40)) for _ in range(30)]
    _, stats = insert_records(index, new, budget=800)
    assert needs_rebuild(stats)
