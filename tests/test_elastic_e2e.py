"""End-to-end elastic restart: train → checkpoint → restore onto a
DIFFERENT mesh layout → continue training with bit-identical state and a
continuous loss trajectory."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.ft import checkpoint as ckpt_mod
from repro.ft.elastic import resume
from repro.models import transformer as tfm
from repro.train import optim, steps


def _batch(cfg, seed):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                                  jnp.int32)}


def test_train_ckpt_remesh_resume(tmp_path):
    cfg = registry.get_module("qwen3-0.6b").reduced()
    ocfg = optim.OptConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    step_fn = jax.jit(steps.make_train_step(
        lambda p, b: tfm.loss_fn(p, b, cfg), ocfg))

    # Phase 1: train 5 steps on mesh A = (data=1, model=1).
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    opt = optim.init(params, ocfg)
    mesh_a = jax.make_mesh((1, 1), ("data", "model"))
    with mesh_a:
        for s in range(5):
            params, opt, met = step_fn(params, opt, _batch(cfg, s))
    d = str(tmp_path / "ck")
    ckpt_mod.save_checkpoint(d, 5, {"params": params, "opt": opt},
                             extra={"seed": 0})

    # Reference: continue 3 more steps uninterrupted.
    p_ref, o_ref = params, opt
    for s in range(5, 8):
        p_ref, o_ref, met_ref = step_fn(p_ref, o_ref, _batch(cfg, s))

    # Phase 2: restore onto mesh B = (data=1,) — different axis layout.
    mesh_b = jax.make_mesh((1,), ("data",))
    state_like = {"params": params, "opt": opt}
    state_axes = {"params": tfm.param_axes(cfg),
                  "opt": optim.opt_state_axes(tfm.param_axes(cfg))}
    state, manifest = resume(d, mesh_b, state_like, state_axes)
    assert manifest["step"] == 5
    # Bit-exact state across the re-mesh.
    for a, b in zip(jax.tree.leaves(state_like), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))

    # Phase 3: continue on mesh B; trajectory matches the reference.
    p2, o2 = state["params"], state["opt"]
    with mesh_b:
        for s in range(5, 8):
            p2, o2, met2 = step_fn(p2, o2, _batch(cfg, s))
    np.testing.assert_allclose(float(met2["loss"]), float(met_ref["loss"]),
                               rtol=1e-5)
    assert int(o2["step"]) == int(o_ref["step"]) == 8
