import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.estimators import (
    gkmv_pair_estimate, gkmv_pair_oracle_np,
    kmv_pair_estimate, kmv_pair_oracle_np,
    buffer_intersection,
)
from repro.core.hashing import hash_u32_np, PAD


def _pack(rows, cap):
    m = len(rows)
    v = np.full((m, cap), PAD, np.uint32)
    n = np.zeros(m, np.int32)
    for i, r in enumerate(rows):
        v[i, : len(r)] = r
        n[i] = len(r)
    return jnp.asarray(v), jnp.asarray(n)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_gkmv_matches_set_oracle(seed):
    rng = np.random.default_rng(seed)
    tau = np.uint32(0.35 * 2**32)
    q_ids = rng.choice(5000, size=300, replace=False)
    qh = np.sort(hash_u32_np(q_ids))
    qk = qh[qh <= tau]

    rows, taus, oracle = [], [], []
    for _ in range(50):
        x_ids = rng.choice(5000, size=rng.integers(20, 400), replace=False)
        xh = np.sort(hash_u32_np(x_ids))
        t = np.uint32(rng.uniform(0.05, 0.35) * 2**32)  # per-record thresholds
        rows.append(xh[xh <= t])
        taus.append(t)
        oracle.append(gkmv_pair_oracle_np(qk, tau, rows[-1], t))

    cap = max(len(r) for r in rows + [qk]) + 3
    xv, xn = _pack(rows, cap)
    qv, qn = _pack([qk], cap)
    d, k, kc = gkmv_pair_estimate(qv[0], qn[0], jnp.uint32(tau), xv, xn,
                                  jnp.asarray(np.asarray(taus, np.uint32)))
    for i, (od, ok, okc) in enumerate(oracle):
        assert int(k[i]) == ok
        assert int(kc[i]) == okc
        np.testing.assert_allclose(float(d[i]), od, rtol=2e-5)


def test_gkmv_pair_identical_records():
    ids = np.arange(100)
    h = np.sort(hash_u32_np(ids))
    tau = np.uint32(PAD - 1)
    cap = 104
    xv, xn = _pack([h], cap)
    d, k, kc = gkmv_pair_estimate(xv[0], xn[0], tau, xv, xn,
                                  jnp.asarray([tau]))
    assert int(kc[0]) == 100 and int(k[0]) == 100
    # (k-1)/U estimates the distinct count of the union (=100) unbiasedly.
    assert 40 < float(d[0]) < 300


@pytest.mark.parametrize("seed", [0, 5])
def test_kmv_matches_set_oracle(seed):
    rng = np.random.default_rng(seed)
    kq, kx = 40, 25
    q_ids = rng.choice(3000, size=500, replace=False)
    qh = np.sort(hash_u32_np(q_ids))[:kq]
    rows, oracle = [], []
    for _ in range(30):
        x_ids = rng.choice(3000, size=rng.integers(30, 600), replace=False)
        xh = np.sort(hash_u32_np(x_ids))[:kx]
        rows.append(xh)
        oracle.append(kmv_pair_oracle_np(qh, xh))
    cap = kq
    xv, xn = _pack(rows, cap)
    qv, qn = _pack([qh], cap)
    d, k, kc = kmv_pair_estimate(qv[0], qn[0], xv, xn)
    for i, (od, ok, okc) in enumerate(oracle):
        assert int(k[i]) == ok, i
        assert int(kc[i]) == okc, i
        np.testing.assert_allclose(float(d[i]), od, rtol=2e-5)


def test_buffer_intersection_popcount():
    rng = np.random.default_rng(0)
    q = rng.integers(0, 2**32, size=4, dtype=np.uint32)
    x = rng.integers(0, 2**32, size=(7, 4), dtype=np.uint32)
    got = np.asarray(buffer_intersection(jnp.asarray(q), jnp.asarray(x)))
    want = [bin(int(q[w]) & int(x[i, w])).count("1") for i in range(7) for w in range(4)]
    want = np.asarray(want).reshape(7, 4).sum(1)
    np.testing.assert_array_equal(got, want)
