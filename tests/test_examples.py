"""Examples must stay runnable (subprocess smoke — the public-API
contract of deliverable (b))."""

import os
import subprocess
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run(script, args=(), timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run(
        [sys.executable, os.path.join(_ROOT, "examples", script), *args],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
    return r.stdout


@pytest.mark.slow
def test_quickstart():
    out = _run("quickstart.py")
    assert "GB-KMV F1" in out


@pytest.mark.slow
def test_lm_dedup_train_short():
    out = _run("lm_dedup_train.py", ["--steps", "30"])
    assert "near-dups removed" in out
    assert "[train] loss" in out


@pytest.mark.slow
def test_recsys_retrieval():
    out = _run("recsys_retrieval.py")
    assert "ranks first" in out


@pytest.mark.slow
def test_containment_serve():
    out = _run("containment_serve.py",
               ["--scale", "0.08", "--batch", "4", "--rounds", "2"])
    assert "[accuracy] F1 vs exact" in out
