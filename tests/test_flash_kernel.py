"""Flash-attention Pallas kernel vs the jnp oracle (interpret mode):
shape/dtype sweep per the kernel-test contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import causal_attention


@pytest.mark.parametrize("b,s,hq,hkv,d,dtype", [
    (1, 256, 4, 2, 64, jnp.float32),
    (2, 256, 8, 8, 32, jnp.float32),     # MHA (G=1)
    (2, 512, 4, 1, 64, jnp.float32),     # MQA (G=4)
    (1, 256, 4, 2, 64, jnp.bfloat16),
])
def test_flash_matches_reference(b, s, hq, hkv, d, dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), dtype)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), dtype)

    out = flash_attention(q, k, v, blk_q=128, blk_k=128, interpret=True)
    ref = causal_attention(q, k, v, chunk_q=128)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_block_shape_sweep():
    rng = np.random.default_rng(1)
    b, s, hq, hkv, d = 1, 512, 2, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    ref = causal_attention(q, k, v, chunk_q=128)
    for blk_q, blk_k in ((64, 128), (128, 64), (256, 256), (512, 128)):
        out = flash_attention(q, k, v, blk_q=blk_q, blk_k=blk_k,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_flash_causality():
    """Future tokens must not influence the output."""
    rng = np.random.default_rng(2)
    b, s, h, d = 1, 256, 2, 32
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    out1 = flash_attention(q, k, v, interpret=True)
    k2 = k.at[:, s // 2:].set(99.0)
    v2 = v.at[:, s // 2:].set(-99.0)
    out2 = flash_attention(q, k2, v2, interpret=True)
    np.testing.assert_allclose(np.asarray(out1[:, : s // 2]),
                               np.asarray(out2[:, : s // 2]),
                               rtol=1e-6, atol=1e-6)
