import numpy as np
import pytest

from repro.core import gbkmv, gkmv, exact, search
from repro.core.hashing import hash_u32_np, PAD
from repro.core.sketches import make_bitmaps, pack_rows
from repro.data.synth import generate_dataset, make_query_workload


def test_global_threshold_budget_exact():
    rng = np.random.default_rng(0)
    rows = [hash_u32_np(rng.choice(10_000, size=s, replace=False))
            for s in rng.integers(5, 200, size=100)]
    budget = 500
    tau = gkmv.select_global_threshold(rows, budget)
    kept = sum(int((r <= tau).sum()) for r in rows)
    assert kept == budget  # exact hit (hashes are collision-free)


def test_global_threshold_keep_all_when_budget_large():
    rows = [hash_u32_np(np.arange(10))]
    tau = gkmv.select_global_threshold(rows, 1000)
    assert tau == np.uint32(PAD - 1)


def test_capacity_overflow_lowers_threshold():
    # Theorem 2 under bounded capacity: a truncated row's effective τ is its
    # largest kept value, so pairwise estimation stays a valid G-KMV.
    rng = np.random.default_rng(1)
    rows = [np.sort(hash_u32_np(rng.choice(10**6, 500, replace=False)))]
    thr = np.asarray([PAD - 1], dtype=np.uint32)
    packed = pack_rows(rows, thr, np.asarray([500]), capacity=64)
    assert packed.capacity == 64
    assert packed.lengths[0] == 64
    assert packed.thresh[0] == rows[0][63]


def test_bitmap_buffer_is_exact():
    records = [np.asarray([1, 2, 3, 7]), np.asarray([2, 3]), np.asarray([9])]
    top = np.asarray([2, 3, 9, 50])
    bm = make_bitmaps(records, top)
    # record0 has top-elems {2,3} -> bits 0,1 ; record2 has {9} -> bit 2
    assert bm[0, 0] == 0b011
    assert bm[1, 0] == 0b011
    assert bm[2, 0] == 0b100


def test_gbkmv_search_beats_kmv_and_matches_exact_direction():
    records = generate_dataset(m=300, n_elems=8000, alpha_freq=1.15,
                               alpha_size=2.2, size_min=30, size_max=800, seed=5)
    einv = exact.build_inverted(records)
    queries = make_query_workload(records, 15, seed=2)
    budget = int(0.15 * sum(len(r) for r in records))

    idx = gbkmv.build_gbkmv(records, budget, r="auto", seed=0)
    res = search.evaluate_engine("gbkmv", idx, einv, queries, threshold=0.5)
    # With 15% budget and self-queries included, GB-KMV must be clearly
    # better than chance and recall-capable.
    assert res["f"] > 0.35
    assert res["recall"] > 0.35


def test_gbkmv_query_contains_self():
    records = generate_dataset(m=100, n_elems=3000, alpha_freq=1.0,
                               alpha_size=2.0, size_min=50, size_max=400, seed=9)
    budget = int(0.3 * sum(len(r) for r in records))
    idx = gbkmv.build_gbkmv(records, budget, r=64, seed=0)
    hits = gbkmv.search(idx, records[7], threshold=0.5)
    assert 7 in hits  # C(Q,Q)=1 — noisy estimate still crosses t*=0.5


def test_gbkmv_r_zero_equals_gkmv():
    records = generate_dataset(m=80, n_elems=2000, alpha_freq=1.2,
                               alpha_size=2.0, size_min=20, size_max=200, seed=4)
    budget = int(0.2 * sum(len(r) for r in records))
    a = gbkmv.build_gbkmv(records, budget, r=0, seed=0)
    b = gkmv.build_gkmv(records, budget, seed=0)
    np.testing.assert_array_equal(np.asarray(a.sketches.values),
                                  np.asarray(b.values))
    np.testing.assert_array_equal(np.asarray(a.sketches.lengths),
                                  np.asarray(b.lengths))


def test_dynamic_insert_keeps_budget():
    # "Processing Dynamic Data" (§IV-B): rebuilding with new records under
    # the same budget tightens τ monotonically.
    recs1 = generate_dataset(m=60, n_elems=2000, alpha_freq=1.1,
                             alpha_size=2.0, size_min=20, size_max=200, seed=6)
    recs2 = recs1 + generate_dataset(m=60, n_elems=2000, alpha_freq=1.1,
                                     alpha_size=2.0, size_min=20, size_max=200, seed=7)
    budget = 800
    t1 = gbkmv.build_gbkmv(recs1, budget, r=0, seed=0).tau
    t2 = gbkmv.build_gbkmv(recs2, budget, r=0, seed=0).tau
    assert t2 <= t1
