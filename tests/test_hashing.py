import numpy as np
import jax.numpy as jnp

from repro.core.hashing import hash_u32, hash_u32_np, unit, unit_np, PAD


def test_np_jnp_agree():
    ids = np.arange(10_000, dtype=np.int64)
    a = hash_u32_np(ids, seed=3)
    b = np.asarray(hash_u32(jnp.asarray(ids), seed=3))
    np.testing.assert_array_equal(a, b)


def test_bijective_on_sample():
    # fmix32 is a bijection on uint32: no collisions over distinct ids.
    ids = np.arange(200_000)
    h = hash_u32_np(ids, seed=0)
    assert len(np.unique(h)) == len(ids)


def test_seed_changes_hash():
    ids = np.arange(1000)
    assert not np.array_equal(hash_u32_np(ids, 0), hash_u32_np(ids, 1))


def test_unit_range():
    v = np.asarray([0, 1, 2**31, 2**32 - 1], dtype=np.uint32)
    u = unit_np(v)
    assert (u > 0).all() and (u <= 1.0).all()
    uj = np.asarray(unit(jnp.asarray(v)))
    np.testing.assert_allclose(u, uj, rtol=1e-6)


def test_uniformity_rough():
    # Mean of hash/2^32 over many ids ≈ 0.5 (avalanche sanity).
    h = unit_np(hash_u32_np(np.arange(100_000)))
    assert abs(h.mean() - 0.5) < 0.01
    assert PAD == np.uint32(0xFFFFFFFF)
