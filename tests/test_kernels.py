"""Pallas kernel validation: interpret-mode vs pure-jnp oracle across a
shape/dtype sweep, plus a hypothesis fuzz over sketch contents."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property fuzzing needs hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.hashing import hash_u32_np, PAD
from repro.kernels import ops
from repro.kernels.ref import gbkmv_score_ref, hash_threshold_ref

settings.register_profile("kern", max_examples=15, deadline=None)
settings.load_profile("kern")


def _rand_index(rng, m, c, w, full_rows=False):
    """Random packed sketches with realistic structure (sorted, PAD-padded)."""
    values = np.full((m, c), PAD, np.uint32)
    lengths = rng.integers(0 if not full_rows else c, c + 1, size=m)
    thresh = rng.integers(1, 2**32 - 2, size=m, dtype=np.uint32)
    for i in range(m):
        n = int(lengths[i])
        if n:
            v = np.unique(rng.integers(0, 2**31, size=n * 2, dtype=np.uint32))[:n]
            values[i, : len(v)] = np.sort(v)
    buf = rng.integers(0, 2**32, size=(m, w), dtype=np.uint32)
    return values, thresh, buf


@pytest.mark.parametrize("m,c,gq,cq,w", [
    (8, 128, 1, 128, 1),      # paper-faithful single query
    (16, 256, 4, 128, 4),     # small batch
    (24, 128, 3, 256, 2),     # query sketch longer than record capacity
    (8, 512, 8, 384, 8),      # wide
    (40, 64, 2, 128, 1),      # capacity not lane-aligned (C free)
])
def test_score_kernel_matches_ref(m, c, gq, cq, w):
    rng = np.random.default_rng(m * 1000 + c + gq)
    xv, xt, xb = _rand_index(rng, m, c, w)
    qv, qt, qb = _rand_index(rng, gq, cq, w)
    qs = rng.integers(1, 500, size=gq).astype(np.int32)

    got = np.asarray(ops.score_index(xv, xt, xb, qv, qt, qb, qs, interpret=True))
    want = np.asarray(gbkmv_score_ref(
        jnp.asarray(xv), jnp.asarray(xt), jnp.asarray(xb),
        jnp.asarray(qv), jnp.asarray(qt), jnp.asarray(qb), jnp.asarray(qs)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_score_kernel_odd_m_padding():
    rng = np.random.default_rng(0)
    xv, xt, xb = _rand_index(rng, 13, 128, 2)   # m not multiple of block
    qv, qt, qb = _rand_index(rng, 2, 128, 2)
    qs = np.asarray([10, 20], np.int32)
    got = np.asarray(ops.score_index(xv, xt, xb, qv, qt, qb, qs, interpret=True))
    assert got.shape == (13, 2)
    want = np.asarray(gbkmv_score_ref(
        jnp.asarray(xv), jnp.asarray(xt), jnp.asarray(xb),
        jnp.asarray(qv), jnp.asarray(qt), jnp.asarray(qb), jnp.asarray(qs)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_score_kernel_empty_buffer():
    rng = np.random.default_rng(1)
    xv, xt, _ = _rand_index(rng, 8, 128, 1)
    qv, qt, _ = _rand_index(rng, 1, 128, 1)
    xb = np.zeros((8, 0), np.uint32)
    qb = np.zeros((1, 0), np.uint32)
    qs = np.asarray([50], np.int32)
    got = np.asarray(ops.score_index(xv, xt, xb, qv, qt, qb, qs, interpret=True))
    assert got.shape == (8, 1)
    assert np.isfinite(got).all()


@given(seed=st.integers(0, 2**16), frac=st.floats(0.01, 1.0),
       n=st.integers(1, 700))
def test_hash_threshold_kernel_fuzz(seed, frac, n):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, 2**31, size=n)
    tau = np.uint32(frac * (2**32 - 2))
    h, keep = ops.hash_and_filter(ids, seed % 97, tau, interpret=True)
    want_h, want_keep = hash_threshold_ref(jnp.asarray(ids), seed % 97, tau)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(want_h))
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(want_keep))
    np.testing.assert_array_equal(np.asarray(h), hash_u32_np(ids, seed % 97))


def test_score_kernel_agrees_with_core_search():
    """Kernel path == core estimator path on a real GB-KMV index."""
    from repro.core import gbkmv
    from repro.core.estimators import gbkmv_containment
    from repro.data.synth import generate_dataset

    records = generate_dataset(m=64, n_elems=3000, alpha_freq=1.2,
                               alpha_size=2.0, size_min=20, size_max=300, seed=2)
    budget = int(0.2 * sum(len(r) for r in records))
    idx = gbkmv.build_gbkmv(records, budget, r=64, seed=0)
    q = gbkmv.sketch_query(idx, records[5])

    want = np.asarray(gbkmv_containment(q, idx.sketches))
    got = np.asarray(ops.score_index(
        idx.sketches.values, idx.sketches.thresh, idx.sketches.buf,
        q.values, q.thresh, q.buf, q.sizes, interpret=True))[:, 0]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
