"""Arena merge/union: bit-identity to rebuild-from-concatenation.

The contract under test (core/arena.merge_arenas and the per-engine
doors merge_gbkmv / merge_gkmv / merge_kmv): parts built over disjoint
record sets with the SAME budget merge into exactly the sketch a
one-shot build over the concatenated records produces — values,
lengths, thresholds, buffers, sizes, and spliced postings, bit for
bit, under any merge grouping. GB-KMV additionally needs the budget to
clear the tail floor ``budget >= m_total * (ceil(r/32) + 1)`` and every
part to share the first part's ``top_elems`` (both are what the
windowed index arranges in production).
"""

import numpy as np
import pytest

from repro import planner
from repro.core import gbkmv, gkmv, kmv
from repro.core.arena import SketchArena, merge_arenas

GBKMV_R = 32  # 1 buffer word/record -> identity floor is budget >= 2*m


def _records(rng, n, universe=3000, lo=4, hi=48):
    return [rng.choice(universe, size=int(rng.integers(lo, hi)),
                       replace=False) for _ in range(n)]


def _split(recs, parts):
    cut = (len(recs) + parts - 1) // parts
    return [recs[i:i + cut] for i in range(0, len(recs), cut)]


def assert_pack_equal(a, b, label=""):
    a, b = SketchArena.from_pack(a), SketchArena.from_pack(b)
    for field in ("values", "lengths", "thresh", "buf", "sizes"):
        x = np.asarray(getattr(a, field))
        y = np.asarray(getattr(b, field))
        assert x.shape == y.shape and np.array_equal(x, y), \
            f"{label}.{field}: merged != rebuilt"


def _gbkmv_parts(slices, budget, seed=0):
    """Epoch-style parts: the first build chooses top_elems, the rest pin
    to it (merge_gbkmv refuses parts with differing buffer sets)."""
    first = gbkmv.build_gbkmv(slices[0], budget, r=GBKMV_R, seed=seed)
    parts = [first] + [
        gbkmv.build_gbkmv(s, budget, r=GBKMV_R, seed=seed,
                          top_elems=first.top_elems) for s in slices[1:]]
    return parts, first.top_elems


@pytest.mark.parametrize("nparts", [2, 4])
def test_gbkmv_merge_matches_rebuild(nparts):
    rng = np.random.default_rng(7)
    recs = _records(rng, 48)
    budget = 4 * len(recs) * 4          # comfortably above the 2*m floor
    parts, top = _gbkmv_parts(_split(recs, nparts), budget)
    merged = gbkmv.merge_gbkmv(parts, budget)
    rebuilt = gbkmv.build_gbkmv(recs, budget, r=GBKMV_R, top_elems=top)
    assert_pack_equal(merged.sketches, rebuilt.sketches, "gbkmv")
    assert int(merged.tau) == int(rebuilt.tau)
    assert np.array_equal(merged.top_elems, rebuilt.top_elems)


@pytest.mark.parametrize("nparts", [2, 3])
def test_gkmv_merge_matches_rebuild(nparts):
    rng = np.random.default_rng(11)
    recs = _records(rng, 40)
    budget = 6 * len(recs)
    parts = [gkmv.build_gkmv(s, budget) for s in _split(recs, nparts)]
    assert_pack_equal(gkmv.merge_gkmv(parts, budget),
                      gkmv.build_gkmv(recs, budget), "gkmv")


def test_kmv_merge_matches_rebuild_uneven_parts():
    # kmv's positional cut is rebuild-identical for ANY part sizes.
    rng = np.random.default_rng(13)
    recs = _records(rng, 37)
    budget = 8 * len(recs)
    slices = [recs[:5], recs[5:6], recs[6:30], recs[30:]]
    parts = [kmv.build_kmv(s, budget) for s in slices]
    assert_pack_equal(kmv.merge_kmv(parts, budget),
                      kmv.build_kmv(recs, budget), "kmv")


def test_merge_arenas_associative_grouping():
    """((a+b)+c) == (a+(b+c)) == one-shot — the windowed index relies on
    this to merge cached intermediate views freely."""
    rng = np.random.default_rng(17)
    recs = _records(rng, 36)
    budget = 5 * len(recs)
    a, b, c = (gkmv.build_gkmv(s, budget) for s in _split(recs, 3))
    left, _ = merge_arenas([merge_arenas([a, b], budget)[0], c], budget)
    right, _ = merge_arenas([a, merge_arenas([b, c], budget)[0]], budget)
    flat, _ = merge_arenas([a, b, c], budget)
    rebuilt = gkmv.build_gkmv(recs, budget)
    for got, label in ((left, "left"), (right, "right"), (flat, "flat")):
        assert_pack_equal(got, rebuilt, f"grouping-{label}")


def test_merged_postings_spliced_not_rebuilt():
    """Part 0's cached postings are tau'-truncated + appended-to; the
    result must be block-for-block identical to a fresh inversion of the
    merged arena."""
    rng = np.random.default_rng(19)
    recs = _records(rng, 44)
    budget = 5 * len(recs)
    parts = [gkmv.build_gkmv(s, budget) for s in _split(recs, 2)]
    parts = [SketchArena.from_pack(p) for p in parts]
    _ = parts[0].postings()                     # materialize the cache
    merged = SketchArena.from_pack(gkmv.merge_gkmv(parts, budget))
    assert merged._post is not None             # splice ran, not lazy
    spliced = merged.postings()
    fresh = planner.build_postings(merged)
    assert planner.postings_equal(spliced, fresh)


def test_gbkmv_merge_rejects_mismatched_parts():
    rng = np.random.default_rng(23)
    recs = _records(rng, 20)
    budget = 8 * len(recs)
    sa, sb = _split(recs, 2)
    a = gbkmv.build_gbkmv(sa, budget, r=GBKMV_R, seed=0)
    with pytest.raises(ValueError, match="seed"):
        gbkmv.merge_gbkmv(
            [a, gbkmv.build_gbkmv(sb, budget, r=GBKMV_R, seed=1)], budget)
    with pytest.raises(ValueError, match="buffer"):
        gbkmv.merge_gbkmv(
            [a, gbkmv.build_gbkmv(sb, budget, r=GBKMV_R, seed=0)], budget)


def test_api_queries_identical_after_merge():
    """The merged arena answers exactly like the rebuilt one through the
    full api/planner stack (threshold + top-k, numpy backend)."""
    from repro import api

    rng = np.random.default_rng(29)
    recs = _records(rng, 40)
    budget = 6 * len(recs)
    parts = [gkmv.build_gkmv(s, budget) for s in _split(recs, 2)]
    merged = api.GKMVEngine.wrap(gkmv.merge_gkmv(parts, budget),
                                 backend="numpy")
    rebuilt = api.get_engine("gkmv").build(recs, budget, backend="numpy")
    queries = [recs[3], recs[25], rng.choice(3000, size=12, replace=False)]
    for t in (0.3, 0.7):
        for hm, hr in zip(merged.batch_query(queries, t),
                          rebuilt.batch_query(queries, t)):
            assert np.array_equal(hm, hr)
    for q in queries:
        im, sm = merged.topk(q, 5)
        ir, sr = rebuilt.topk(q, 5)
        assert np.array_equal(im, ir) and np.array_equal(sm, sr)


# -- hypothesis: identity holds for arbitrary sizes and groupings ----------
# Guarded import (not importorskip) so the deterministic tests above
# still run in environments without hypothesis.

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    settings.register_profile("merge", max_examples=20, deadline=None)
    settings.load_profile("merge")

    @st.composite
    def corpus_and_cuts(draw):
        m = draw(st.integers(min_value=4, max_value=24))
        seed = draw(st.integers(min_value=0, max_value=2**16))
        rng = np.random.default_rng(seed)
        recs = _records(rng, m, universe=600, lo=2, hi=24)
        ncuts = draw(st.integers(min_value=1, max_value=3))
        cuts = sorted(draw(st.sets(
            st.integers(min_value=1, max_value=m - 1),
            min_size=ncuts, max_size=ncuts)))
        extra = draw(st.integers(min_value=0, max_value=4 * m))
        return recs, cuts, extra

    @given(corpus_and_cuts())
    def test_gkmv_merge_identity_property(case):
        recs, cuts, extra = case
        budget = 2 * len(recs) + extra  # any shared budget works for gkmv
        bounds = [0] + cuts + [len(recs)]
        slices = [recs[a:b] for a, b in zip(bounds[:-1], bounds[1:])]
        parts = [gkmv.build_gkmv(s, budget) for s in slices]
        assert_pack_equal(gkmv.merge_gkmv(parts, budget),
                          gkmv.build_gkmv(recs, budget), "gkmv-prop")

    @given(corpus_and_cuts())
    def test_gbkmv_merge_identity_property(case):
        recs, cuts, extra = case
        m = len(recs)
        # identity regime: budget clears the m*(ceil(r/32)+1) tail floor
        budget = m * (GBKMV_R // 32 + 1) + m + extra
        bounds = [0] + cuts + [m]
        slices = [recs[a:b] for a, b in zip(bounds[:-1], bounds[1:])]
        parts, top = _gbkmv_parts(slices, budget)
        merged = gbkmv.merge_gbkmv(parts, budget)
        if len(parts) > 2:              # grouping must not matter
            head = gbkmv.merge_gbkmv(parts[:2], budget)
            merged2 = gbkmv.merge_gbkmv([head] + parts[2:], budget)
            assert_pack_equal(merged.sketches, merged2.sketches,
                              "gbkmv-assoc")
            assert int(merged.tau) == int(merged2.tau)
        rebuilt = gbkmv.build_gbkmv(recs, budget, r=GBKMV_R, top_elems=top)
        assert_pack_equal(merged.sketches, rebuilt.sketches, "gbkmv-prop")
        assert int(merged.tau) == int(rebuilt.tau)
else:
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_merge_identity_property():
        pass
