"""Multi-device shard_map correctness: runs an 8-host-device subprocess
(the XLA device-count flag must precede jax import, so these cannot run
in the main pytest process, which pins 1 CPU device)."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp

from repro.data.synth import generate_dataset, make_query_workload
from repro.core.gbkmv import build_gbkmv, sketch_query
from repro.core import gbkmv as G
from repro.sketchindex import (batch_queries, distributed_tau,
                               distributed_topk, score_batch, to_device_index)
from repro.sketchindex.build import histogram_tau
from repro.launch.mesh import make_mesh

mesh = make_mesh((4, 2), ("data", "model"))

# --- distributed_topk vs numpy on an 8-way sharded score matrix ---
rng = np.random.default_rng(0)
scores = jnp.asarray(rng.normal(size=(160, 5)), jnp.float32)
v, i = distributed_topk(scores, 7, mesh)
ref = np.sort(np.asarray(scores), axis=0)[::-1][:7].T
np.testing.assert_allclose(np.asarray(v), ref, rtol=1e-6)
picked = np.take_along_axis(np.asarray(scores), np.asarray(i).T, axis=0).T
np.testing.assert_allclose(picked, np.asarray(v), rtol=1e-6)
print("topk-ok")

# --- sharded scoring == host oracle ---
recs = generate_dataset(m=96, n_elems=4000, alpha_freq=1.1, alpha_size=2.0,
                        seed=0)
idx = build_gbkmv(recs, budget=2000, r=32)
didx = to_device_index(idx, mesh)
queries = make_query_workload(recs, 3)
qp = batch_queries(idx, queries)
sc = np.asarray(score_batch(didx, qp))
for j, q in enumerate(queries):
    host = np.asarray(G.containment_scores(idx, sketch_query(idx, q)))
    np.testing.assert_allclose(sc[: idx.num_records, j], host,
                               rtol=1e-5, atol=1e-5)
print("score-ok")

# --- distributed τ (psum histogram) == single-device histogram ---
h = rng.integers(0, 2**32, size=16384).astype(np.uint32)
t1 = int(histogram_tau(jnp.asarray(h), 900))
t2 = int(distributed_tau(jnp.asarray(h), 900, mesh, ("data", "model")))
assert t1 == t2, (hex(t1), hex(t2))
print("tau-ok")
"""


def test_shard_map_paths_on_8_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=420, env=env)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    for marker in ("topk-ok", "score-ok", "tau-ok"):
        assert marker in r.stdout, (marker, r.stdout[-500:])
