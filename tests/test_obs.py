"""Observability tests: tracer/span primitives under a fake clock,
Chrome trace export, no-op cost when nothing is attached, stage
profiler + cost drift, histogram quantile edge cases, per-tenant rate
limiting, and — the load-bearing contract — explain-vs-reality parity:
the numbers ``batch_query(..., explain=True)`` reports must equal the
planner's independently recomputed internals, across engines and
backends."""

import json

import numpy as np
import pytest

from repro import api
from repro.data.synth import generate_dataset, make_query_workload
from repro.obs import (
    NULL_TRACER, CostDrift, NullTracer, StageProfiler, Tracer, attach,
    current_trace, stage)
from repro.planner import candidates_for
from repro.planner.plan import probe_hits_per_query
from repro.service import (
    AsyncSketchServer, ServiceApp, ServiceClient, ServiceError,
    ServiceHandle, TenantBuckets, parse_prometheus, tenant_id)
from repro.serving import Histogram


class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


# -- tracer / span primitives ------------------------------------------------


def test_trace_span_nesting_and_durations():
    clk = FakeClock()
    tracer = Tracer(capacity=4, clock=clk)
    tr = tracer.begin("query", rid=7)
    clk.t = 1.0
    with tr.span("plan") as outer:
        clk.t = 1.5
        with tr.span("probe", shards=2) as inner:
            clk.t = 2.0
        outer.set(hits=3)
        clk.t = 3.0
    clk.t = 4.0
    tr.end()

    assert tr.root.duration == pytest.approx(4.0)
    names = {s.name: s for s in tr.spans}
    assert names["plan"].duration == pytest.approx(2.0)
    assert names["probe"].duration == pytest.approx(0.5)
    assert names["probe"].parent is names["plan"]
    assert names["plan"].parent is tr.root
    assert names["plan"].attrs["hits"] == 3
    assert names["probe"].attrs["shards"] == 2
    assert tr.root.attrs["rid"] == 7


def test_tracer_ring_buffer_evicts_oldest():
    clk = FakeClock()
    tracer = Tracer(capacity=3, clock=clk)
    for i in range(5):
        tracer.begin(f"t{i}").end()
    recent = tracer.recent()
    assert [t.root.name for t in recent] == ["t2", "t3", "t4"]
    tracer.clear()
    assert tracer.recent() == []


def test_trace_end_is_idempotent():
    clk = FakeClock()
    tracer = Tracer(capacity=4, clock=clk)
    tr = tracer.begin("q")
    tr.end()
    tr.end()
    assert len(tracer.recent()) == 1


def test_chrome_trace_export_shape():
    clk = FakeClock(10.0)
    tracer = Tracer(capacity=4, clock=clk)
    tr = tracer.begin("query", rid=1)
    clk.t = 10.001
    with tr.span("score"):
        clk.t = 10.003
    tr.end()
    doc = tracer.chrome_trace()
    # Must round-trip through JSON (the /debug/traces body).
    doc = json.loads(json.dumps(doc))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert {e["name"] for e in evs} == {"query", "score"}
    for e in evs:
        assert e["ph"] == "X"
        assert isinstance(e["ts"], (int, float))
        assert e["dur"] >= 0
    score = next(e for e in evs if e["name"] == "score")
    assert score["dur"] == pytest.approx(2000.0)  # 2ms in µs


def test_null_tracer_and_unattached_stage_are_inert():
    tr = NullTracer().begin("anything", rid=1)
    with tr.span("x") as s:
        s.set(a=1)
    tr.end()
    assert NULL_TRACER.chrome_trace() == {"traceEvents": [],
                                          "displayTimeUnit": "ms"}
    assert current_trace() is None
    # No attach → the shared no-op context; sync passes values through.
    with stage("planner.probe", foo=1) as s:
        assert s.sync(42) == 42
        s.set(bar=2)


def test_attach_routes_stages_to_trace_and_profiler():
    clk = FakeClock()
    tracer = Tracer(capacity=4, clock=clk)
    prof = StageProfiler()
    tr = tracer.begin("batch")
    with attach(tr, prof):
        assert current_trace() is tr
        clk.t = 0.5
        with stage("planner.probe", shards=1) as s:
            clk.t = 0.75
            s.set(hits=9)
    tr.end()
    span = next(s for s in tr.spans if s.name == "planner.probe")
    assert span.duration == pytest.approx(0.25)
    assert span.attrs["hits"] == 9
    assert "planner.probe" in prof.stages()
    assert prof.snapshot()["planner.probe"]["count"] == 1
    # Attached region is scoped: gone after the with block.
    assert current_trace() is None


def test_add_span_with_explicit_times():
    clk = FakeClock()
    tracer = Tracer(capacity=2, clock=clk)
    tr = tracer.begin("req")
    tr.add_span("queue_wait", 1.0, 3.5, depth=2)
    tr.end()
    s = next(x for x in tr.spans if x.name == "queue_wait")
    assert s.duration == pytest.approx(2.5)
    assert s.parent is tr.root


# -- stage profiler + cost drift ---------------------------------------------


def test_stage_profiler_histograms_and_snapshot():
    prof = StageProfiler()
    for v in (0.001, 0.002, 0.004):
        prof.observe("serve.score", v)
    prof.observe("serve.topk", 0.01)
    snap = prof.snapshot()
    assert snap["serve.score"]["count"] == 3
    assert snap["serve.score"]["mean_s"] == pytest.approx(0.00233, rel=0.1)
    fams = prof.histograms()
    assert set(fams) == {'stage="serve.score"', 'stage="serve.topk"'}
    assert all(isinstance(h, Histogram) for h in fams.values())


def test_cost_drift_self_fits_and_converges():
    d = CostDrift()
    assert d.drift == 0.0                  # nothing measurable yet
    for _ in range(8):
        d.record(1000.0, 0.01)             # perfectly consistent flushes
    assert d.drift == pytest.approx(1.0, rel=0.05)
    # Garbage inputs never poison the estimate.
    d.record(float("nan"), 0.01)
    d.record(1000.0, 0.0)
    assert np.isfinite(d.drift)


# -- histogram quantile edge cases (satellite fix) ---------------------------


def test_histogram_quantile_empty_is_zero():
    h = Histogram()
    assert h.quantile(0.0) == 0.0
    assert h.quantile(0.5) == 0.0
    assert h.quantile(1.0) == 0.0


def test_histogram_quantile_rejects_out_of_range():
    h = Histogram()
    h.observe(0.01)
    with pytest.raises(ValueError):
        h.quantile(-0.1)
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_histogram_quantile_single_observation():
    h = Histogram()
    h.observe(0.0123)
    lo, hi = h.quantile(0.0), h.quantile(1.0)
    # q=0 → lower edge of the occupied bucket, q=1 → its upper edge,
    # and the observation sits between them.
    assert lo <= 0.0123 <= hi
    assert lo > 0.0                        # not the empty underflow bucket
    for q in (0.25, 0.5, 0.9):
        assert lo <= h.quantile(q) <= hi


def test_histogram_quantile_extremes_bracket_observations():
    h = Histogram()
    vals = [0.001, 0.005, 0.02, 0.1, 0.4]
    for v in vals:
        h.observe(v)
    assert h.quantile(0.0) <= min(vals)
    assert h.quantile(1.0) >= max(vals)
    qs = [h.quantile(q) for q in np.linspace(0, 1, 11)]
    assert all(a <= b + 1e-12 for a, b in zip(qs, qs[1:]))  # monotonic


# -- per-tenant rate limiting ------------------------------------------------


def test_tenant_id_header_forms_and_hashing():
    assert tenant_id({}) == "anon"
    a = tenant_id({"X-Auth-Token": "secret-a"})
    b = tenant_id({"Authorization": "Bearer secret-b"})
    assert a != b and a != "anon"
    assert "secret-a" not in a and len(a) == 12      # hashed, never raw
    # Same credential through either header → same tenant.
    assert tenant_id({"Authorization": "Bearer secret-a"}) == a


def test_tenant_buckets_isolate_tenants():
    clk = FakeClock()
    tb = TenantBuckets(rate=1.0, burst=2, clock=clk)
    assert tb.allow("a") and tb.allow("a")
    assert not tb.allow("a")               # a exhausted its burst
    assert tb.allow("b")                   # b unaffected
    assert tb.retry_after("a") > 0.0
    clk.t = 5.0                            # refill
    assert tb.allow("a")


def test_tenant_buckets_disabled_and_eviction():
    assert TenantBuckets(rate=None).allow("anyone")
    clk = FakeClock()
    tb = TenantBuckets(rate=1.0, burst=1, clock=clk, max_tenants=2)
    assert tb.allow("a") and tb.allow("b")
    assert tb.allow("c")                   # evicts a (LRU)
    assert tb.allow("a")                   # a restarts with a full burst


def test_http_tenant_rate_limit_429_and_metric():
    from tests.test_service import StubIndex

    srv = AsyncSketchServer(StubIndex(), max_batch=4, max_wait=0.002)
    app = ServiceApp(srv, tenant_rate_limit=1e-6, tenant_burst=2)
    with ServiceHandle(app) as h:
        a = ServiceClient(*h.address, token="tenant-a")
        b = ServiceClient(*h.address, token="tenant-b")
        a.query(np.arange(3), 0.5)
        a.query(np.arange(3), 0.5)         # a's burst exhausted
        with pytest.raises(ServiceError) as ei:
            a.query(np.arange(3), 0.5)
        assert ei.value.status == 429 and ei.value.retry_after > 0
        b.query(np.arange(3), 0.5)         # b unaffected
        text = a.metrics_text()
        pm = parse_prometheus(text)
        tid = tenant_id({"Authorization": "Bearer tenant-a"})
        assert pm[f'service_ratelimited_total{{tenant="{tid}"}}'] == 1.0
        assert "tenant-a" not in text      # raw credential never exported
        a.close(), b.close()


# -- explain vs planner reality ----------------------------------------------

EXPLAIN_THRESHOLD = 0.8


@pytest.fixture(scope="module", params=["gbkmv", "gkmv", "kmv"])
def explain_setup(request):
    engine = request.param
    recs = generate_dataset(m=150, n_elems=4000, alpha_freq=0.9,
                            alpha_size=1.5, seed=3)
    budget = sum(len(r) for r in recs) // 5
    built = {bk: api.get_engine(engine).build(recs, budget, seed=0,
                                              backend=bk)
             for bk in ("numpy", "jnp")}
    return engine, built, make_query_workload(recs, 6, seed=1)


@pytest.mark.parametrize("backend", ["numpy", "jnp"])
def test_explain_pruned_matches_planner_internals(explain_setup, backend):
    engine, built, queries = explain_setup
    idx = built[backend]
    t = EXPLAIN_THRESHOLD
    hits, ex = idx.batch_query(queries, t, plan="pruned", explain=True)
    plain = idx.batch_query(queries, t, plan="pruned")
    assert len(ex) == len(queries)
    for h, p in zip(hits, plain):          # explain must not change answers
        np.testing.assert_array_equal(h, p)

    # Recompute the planner's internals independently and require the
    # explain numbers to match them exactly.
    _, hash_rows, bit_rows, q_sizes = idx._plan_queries(queries)
    post = idx._postings()
    probe = probe_hits_per_query(post, hash_rows, bit_rows)
    for g, e in enumerate(ex):
        assert e["plan"] == "pruned"
        assert e["engine"] == engine and e["backend"] == backend
        assert e["threshold"] == pytest.approx(t)
        assert e["hits"] == len(hits[g])
        assert e["probe_hits"] == int(probe[g])
        c = candidates_for(post, hash_rows[g], bit_rows[g], t,
                           int(q_sizes[g]))
        assert e["candidates"] == len(c.rec_ids)
        assert e["pruned"] == c.pruned
        assert e["blocks"] == c.blocks
        assert e["skipped_blocks"] == c.skipped_blocks
        assert e["merge_hits"] == c.hits
        cost = e["cost"]
        assert cost["est_pruned"] > 0
        assert cost["predicted_units"] == pytest.approx(cost["est_pruned"])
        assert cost["measured_seconds"] > 0


@pytest.mark.parametrize("backend", ["numpy", "jnp"])
def test_explain_dense_has_no_planner_fields(explain_setup, backend):
    engine, built, queries = explain_setup
    idx = built[backend]
    hits, ex = idx.batch_query(queries, 0.5, plan="dense", explain=True)
    for g, e in enumerate(ex):
        assert e["plan"] == "dense"
        assert e["hits"] == len(hits[g])
        for key in ("probe_hits", "candidates", "blocks", "skipped_blocks",
                    "tau", "ub_max"):
            assert key not in e
        assert e["cost"]["predicted_units"] == pytest.approx(
            e["cost"]["est_dense"])


def test_explain_single_query_form():
    recs = generate_dataset(m=60, n_elems=2000, alpha_freq=1.0,
                            alpha_size=2.0, seed=4)
    idx = api.get_engine("gbkmv").build(
        recs, sum(len(r) for r in recs) // 5, backend="numpy")
    hits, e = idx.query(recs[0], 0.5, explain=True)
    assert isinstance(e, dict) and e["plan"] in ("dense", "pruned")
    np.testing.assert_array_equal(hits, idx.query(recs[0], 0.5))
    assert idx.last_explain is not None


# -- live HTTP: explain + debug endpoints ------------------------------------


@pytest.fixture(scope="module")
def live_obs_service():
    from repro.launch.mesh import make_mesh
    from repro.sketchindex import ShardedIndex

    recs = generate_dataset(m=100, n_elems=3000, alpha_freq=1.1,
                            alpha_size=2.0, seed=0)
    index = api.get_engine("gbkmv").build(
        recs, sum(len(r) for r in recs) // 5)
    sharded = ShardedIndex(index, make_mesh((1, 1), ("data", "model")))
    srv = AsyncSketchServer(sharded, max_batch=4, max_wait=0.002,
                            tracer=Tracer(capacity=32), slow_threshold=0.0)
    with ServiceHandle(ServiceApp(srv)) as h:
        yield h, sharded, make_query_workload(recs, 4, seed=1)


def test_http_query_explain_round_trip(live_obs_service):
    h, sharded, queries = live_obs_service
    cli = ServiceClient(*h.address)
    hits, e = cli.query_explain(queries[0], EXPLAIN_THRESHOLD)
    np.testing.assert_array_equal(
        hits, sharded.batch_query([queries[0]], EXPLAIN_THRESHOLD)[0])
    assert e["plan"] in ("dense", "pruned")
    assert e["threshold"] == pytest.approx(EXPLAIN_THRESHOLD)
    assert "cost" in e and e["cost"]["measured_seconds"] > 0
    # Plain queries never carry the explain payload.
    status, raw, _ = cli.request(
        "POST", "/query",
        body=json.dumps({"q": queries[0].tolist(), "threshold": 0.5}
                        ).encode())
    assert status == 200 and "explain" not in json.loads(raw)
    # /debug/explain forces it regardless of the body.
    status, raw, _ = cli.request(
        "POST", "/debug/explain",
        body=json.dumps({"q": queries[0].tolist(), "threshold": 0.5}
                        ).encode())
    assert status == 200 and json.loads(raw)["explain"]["plan"] in (
        "dense", "pruned")
    cli.close()


def test_http_debug_traces_chrome_loadable(live_obs_service):
    h, _, queries = live_obs_service
    cli = ServiceClient(*h.address)
    cli.query(queries[0], 0.5)
    doc = cli.debug_traces()
    evs = doc["traceEvents"]
    assert evs and doc["displayTimeUnit"] == "ms"
    for e in evs:                          # chrome trace-event contract
        assert e["ph"] == "X"
        for k in ("name", "ts", "dur", "pid", "tid"):
            assert k in e
    names = {e["name"] for e in evs}
    assert "query" in names and "flush.execute" in names
    assert "queue_wait" in names and "execute" in names
    cli.close()


def test_http_slow_log_and_obs_metrics(live_obs_service):
    h, _, queries = live_obs_service
    cli = ServiceClient(*h.address)
    cli.query(queries[0], 0.5)
    slow = cli.debug_slow()                # threshold 0.0 → everything slow
    assert slow["count"] >= 1 and slow["recent"]
    entry = slow["recent"][0]
    for k in ("rid", "kind", "latency_s", "queue_wait_s", "plan"):
        assert k in entry
    pm = parse_prometheus(cli.metrics_text())
    assert pm["service_slow_queries_total"] >= 1
    assert "service_cost_model_drift" in pm
    stage_counts = [k for k in pm
                    if k.startswith("service_stage_latency_seconds_count")]
    assert any('stage="flush.execute"' in k for k in stage_counts)
    cli.close()


def test_debug_endpoints_require_auth():
    from tests.test_service import StubIndex

    srv = AsyncSketchServer(StubIndex(), max_batch=4, max_wait=0.002,
                            tracer=Tracer(capacity=8))
    with ServiceHandle(ServiceApp(srv, auth_token="hunter2")) as h:
        anon = ServiceClient(*h.address)
        for path in ("/debug/traces", "/debug/slow"):
            status, _, _ = anon.request("GET", path)
            assert status == 401
        status, _, _ = anon.request("POST", "/debug/explain",
                                    body=b"{}")
        assert status == 401
        authed = ServiceClient(*h.address, token="hunter2")
        assert authed.debug_traces()["displayTimeUnit"] == "ms"
        status, _, _ = authed.request("POST", "/debug/traces")
        assert status == 405               # GET-only
        anon.close(), authed.close()
