"""Candidate-pruning query planner: postings maintenance, pruned-vs-dense
exact parity (all engines × all backends, before and after inserts),
plan selection, and the ragged gather-score kernel."""

import jax
import numpy as np
import pytest

from repro import api, planner
from repro.core.hashing import hash_u32_np
from repro.data.synth import generate_dataset, make_query_workload
from repro.planner import prune

ENGINES = ("gbkmv", "gkmv", "kmv")
BACKENDS = ("numpy", "jnp", "pallas")


@pytest.fixture(scope="module")
def corpus():
    recs = generate_dataset(m=130, n_elems=4000, alpha_freq=1.0,
                            alpha_size=1.6, seed=0)
    total = sum(len(r) for r in recs)
    queries = make_query_workload(recs, 6, seed=1)
    # Off-corpus queries too: partial overlaps, not guaranteed self-hits.
    rng = np.random.default_rng(3)
    queries += [rng.choice(4000, size=s, replace=False)
                for s in (5, 40, 160)]
    return recs, total, queries


@pytest.fixture(scope="module")
def gb_index(corpus):
    recs, total, _ = corpus
    return api.get_engine("gbkmv").build(recs, int(total * 0.1))


# ---------------------------------------------------------------------------
# postings: CSR structure + incremental maintenance
# ---------------------------------------------------------------------------


def test_postings_csr_structure(gb_index):
    s = gb_index.core.sketches
    post = planner.build_postings(s)
    assert post.num_records == s.num_records
    assert np.all(np.diff(post.keys.astype(np.int64)) > 0)       # strict asc
    assert post.offsets[0] == 0 and post.offsets[-1] == post.nnz
    assert np.all(np.diff(post.offsets) >= 1)     # no empty hash rows
    assert post.nnz == int(np.asarray(s.lengths).sum())
    # Every (hash, record) pair is findable, rec lists ascending per key.
    vals, lens = np.asarray(s.values), np.asarray(s.lengths)
    for i in (0, s.num_records // 2, s.num_records - 1):
        for h in vals[i, : lens[i]][:20]:
            j = int(np.searchsorted(post.keys, h))
            seg = post.rec_ids[post.offsets[j] : post.offsets[j + 1]]
            assert post.keys[j] == h and i in seg
            assert np.all(np.diff(seg) > 0)
    assert post.nbytes() > 0


def test_postings_buffer_rows(gb_index):
    s = gb_index.core.sketches
    post = planner.build_postings(s)
    if s.buf_words == 0:
        pytest.skip("cost model chose r=0 for this corpus")
    bits = ((np.asarray(s.buf)[:, :, None]
             >> np.arange(32, dtype=np.uint32)) & 1).reshape(s.num_records, -1)
    for j in range(min(bits.shape[1], 48)):
        seg = post.buf_rec_ids[post.buf_offsets[j] : post.buf_offsets[j + 1]]
        np.testing.assert_array_equal(seg, np.nonzero(bits[:, j])[0])


def test_incremental_update_equals_rebuild(corpus, gb_index):
    recs, total, _ = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.06))
    idx._postings()                          # build before the insert
    extra = generate_dataset(m=50, n_elems=4000, alpha_freq=1.0,
                             alpha_size=1.6, seed=7)
    idx.insert(extra)
    assert idx.stats.tau_retightens >= 1     # deletion path exercised
    fresh = planner.build_postings(idx.core.sketches)
    assert planner.postings_equal(idx._post, fresh)


def test_incremental_update_without_retighten(corpus):
    recs, total, _ = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 10))  # roomy budget
    idx._postings()
    idx.insert([np.asarray([1, 2, 3]), np.asarray([4, 5])])
    assert idx.stats.tau_retightens == 0     # append-only path
    assert planner.postings_equal(
        idx._post, planner.build_postings(idx.core.sketches))


# ---------------------------------------------------------------------------
# parity: pruned == dense, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("backend", BACKENDS)
def test_pruned_matches_dense(corpus, engine, backend):
    recs, total, queries = corpus
    idx = api.get_engine(engine).build(recs, int(total * 0.1), backend=backend)
    for t in (0.3, 0.6, 0.9):
        dense = idx.batch_query(queries, t, plan="dense")
        pruned = idx.batch_query(queries, t, plan="pruned")
        auto = idx.batch_query(queries, t)
        for d, p, a in zip(dense, pruned, auto):
            np.testing.assert_array_equal(d, p)
            np.testing.assert_array_equal(d, a)


@pytest.mark.parametrize("engine", ENGINES)
def test_single_query_plan_kw(corpus, engine):
    recs, total, queries = corpus
    idx = api.get_engine(engine).build(recs, int(total * 0.1))
    q = queries[0]
    np.testing.assert_array_equal(idx.query(q, 0.5, plan="pruned"),
                                  idx.query(q, 0.5, plan="dense"))
    np.testing.assert_array_equal(idx.query(q, 0.5), idx.query(q, 0.5,
                                                               plan="dense"))


@pytest.mark.parametrize("backend", BACKENDS)
def test_parity_after_insert_retighten(corpus, backend):
    recs, total, queries = corpus
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.06),
                                        backend=backend)
    idx._postings()
    extra = generate_dataset(m=40, n_elems=4000, alpha_freq=1.0,
                             alpha_size=1.6, seed=9)
    idx.insert(extra)
    assert idx.stats.tau_retightens >= 1
    for t in (0.4, 0.8):
        dense = idx.batch_query(queries, t, plan="dense")
        pruned = idx.batch_query(queries, t, plan="pruned")
        for d, p in zip(dense, pruned):
            np.testing.assert_array_equal(d, p)


def test_candidates_never_drop_a_hit(corpus, gb_index):
    """The filter step alone (before verify) is a superset of the dense
    hits — pruning never drops a record with estimated containment ≥ t."""
    recs, total, queries = corpus
    post = gb_index._postings()
    for t in (0.2, 0.5, 0.8):
        _, hash_rows, bit_rows, sizes = gb_index._plan_queries(
            [np.asarray(q) for q in queries])
        dense = gb_index.batch_query(queries, t, plan="dense")
        for qh, qb, qs, hits in zip(hash_rows, bit_rows, sizes, dense):
            cand = prune.candidates_for(post, qh, qb, t, int(qs))
            assert set(hits.tolist()) <= set(cand.rec_ids.tolist())


def test_bound_survives_f32_rounding_of_buffer_scores():
    """A buffer-only score like o1/|Q| = 1/3 rounds UP in float32
    (fl32(1/3) > 1/3), so for thresholds inside (1/3, fl32(1/3)] the
    dense sweep returns the record while the exact real-valued bound
    sits below t — the bound's slack must absorb that, or pruning drops
    a dense hit."""
    # Element 0 is ubiquitous -> buffered; records share ONLY it with Q.
    recs = [np.asarray([0, 100 + i, 200 + i, 300 + i]) for i in range(20)]
    idx = api.get_engine("gbkmv").build(recs, budget=400, r=32)
    assert 0 in idx.core.top_elems
    q = np.asarray([0, 9001, 9002])          # |Q|=3, only elem 0 shared
    s = idx.scores(q)
    t = float(np.float32(1 / 3))             # == fl32(1/3) > 1/3
    assert s.max() == np.float32(1 / 3)      # buffer-only score, rounded up
    dense = idx.batch_query([q], t, plan="dense")[0]
    pruned = idx.batch_query([q], t, plan="pruned")[0]
    assert len(dense) > 0                    # the edge actually triggers
    np.testing.assert_array_equal(dense, pruned)


# ---------------------------------------------------------------------------
# plan selection
# ---------------------------------------------------------------------------


def test_plan_guards_and_forcing(corpus, gb_index):
    recs, total, queries = corpus
    _, hash_rows, bit_rows, _ = gb_index._plan_queries(
        [np.asarray(q) for q in queries[:2]])
    post = gb_index._postings()
    s = gb_index.core.sketches
    # t <= 0: pruning is unsound, always dense (even when forced).
    d = planner.choose_plan(post, hash_rows, bit_rows, 0.0,
                            s.num_records, s.capacity, plan="pruned")
    assert d.path == "dense"
    for mode in ("dense", "pruned"):
        d = planner.choose_plan(post, hash_rows, bit_rows, 0.9,
                                s.num_records, s.capacity, plan=mode)
        assert d.path == mode and d.reason == "forced"
    with pytest.raises(ValueError):
        planner.normalize_plan("fastest")
    # Auto obeys the cost ordering on both extremes of index size.
    auto = planner.choose_plan(post, hash_rows, bit_rows, 0.9,
                               s.num_records, s.capacity)
    assert auto.path in ("dense", "pruned") and auto.hits > 0
    big_m = planner.choose_plan(post, hash_rows, bit_rows, 0.9,
                                10_000_000, s.capacity)
    assert big_m.path == "pruned"    # selective probe vs huge sweep


def test_topk_scores_match_dense_ranking(corpus, gb_index):
    _, _, queries = corpus
    ids, scores = gb_index.topk(queries[0], 5)   # auto plan routing
    s = gb_index.scores(queries[0])
    np.testing.assert_allclose(scores, np.sort(s)[::-1][:5], rtol=1e-6)


# ---------------------------------------------------------------------------
# packed thresholding + float32 threshold exactness
# ---------------------------------------------------------------------------


def test_threshold_hits_packed_matches_nonzero():
    rng = np.random.default_rng(0)
    s = rng.random((50, 7)).astype(np.float32)
    for t in (0.3, 0.7, float(s[3, 2])):
        want = [np.nonzero(s[:, j] >= t)[0] for j in range(7)]
        got = prune.threshold_hits_packed(s, t)
        got_dev = prune.threshold_hits_packed(jax.numpy.asarray(s), t)
        for w, g, gd in zip(want, got, got_dev):
            np.testing.assert_array_equal(w, g)
            np.testing.assert_array_equal(w, gd)
    thr = rng.random(7)
    want = [np.nonzero(s[:, j] >= thr[j])[0] for j in range(7)]
    for w, g in zip(want, prune.threshold_hits_packed(s, thr)):
        np.testing.assert_array_equal(w, g)


def test_f32_threshold_is_exact():
    for t in (0.7, 0.1, 1 / 3, 0.5, 0.9999999):
        up = prune.f32_threshold(t)
        grid = np.nextafter(np.float32(t),
                            np.float32([-np.inf, np.inf])).tolist()
        for s in [np.float32(t)] + [np.float32(g) for g in grid]:
            assert (s >= up) == (float(s) >= t)


# ---------------------------------------------------------------------------
# ragged gather-score kernel
# ---------------------------------------------------------------------------


def test_gather_kernel_backends_agree(corpus, gb_index):
    from repro.kernels import gather_score
    from repro.sketchindex.distributed import batch_queries

    recs, total, queries = corpus
    qp = batch_queries(gb_index.core, [np.asarray(q) for q in queries])
    m = gb_index.num_records
    rng = np.random.default_rng(1)
    cand_rec = rng.integers(0, m, size=37).astype(np.int32)
    cand_q = rng.integers(0, len(queries), size=37).astype(np.int32)
    x = gb_index.core.sketches
    s_np = gather_score.score_pairs(x, qp, cand_rec, cand_q, backend="numpy")
    s_jnp = gather_score.score_pairs(x, qp, cand_rec, cand_q, backend="jnp")
    s_pl = gather_score.score_pairs(x, qp, cand_rec, cand_q, backend="pallas")
    np.testing.assert_allclose(s_np, s_jnp, rtol=1e-6)
    np.testing.assert_allclose(s_jnp, s_pl, rtol=1e-6)
    # ... and each pair equals the dense matrix entry it addresses.
    dense = gb_index.batch_scores(queries)
    np.testing.assert_allclose(s_jnp, dense[cand_rec, cand_q], rtol=1e-6)


# ---------------------------------------------------------------------------
# distributed + serving wiring
# ---------------------------------------------------------------------------


def test_sharded_planner_matches_dense(corpus, gb_index):
    from repro.sketchindex import ShardedIndex

    _, _, queries = corpus
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = ShardedIndex(gb_index, mesh)
    posts, offs = sh._shard_postings()
    assert len(posts) == 1 and offs == [0]
    for t in (0.4, 0.8):
        dense = sh.batch_query(queries, t, plan="dense")
        pruned = sh.batch_query(queries, t, plan="pruned")
        host = gb_index.batch_query(queries, t, plan="dense")
        for d, p, h in zip(dense, pruned, host):
            np.testing.assert_array_equal(d, p)
            np.testing.assert_array_equal(d, h)


def test_shard_union_equals_global(gb_index):
    """Cross-shard candidate union == single global postings' candidates."""
    post_global = gb_index._postings()
    s = gb_index.core.sketches
    qp, hash_rows, bit_rows, sizes = gb_index._plan_queries(
        [np.arange(10), np.arange(50, 90)])
    # Split the records into 3 artificial shards.
    import dataclasses

    cuts = [0, 40, 90, s.num_records]
    posts, offs = [], []
    for lo, hi in zip(cuts[:-1], cuts[1:]):
        sub = dataclasses.replace(
            s, values=np.asarray(s.values)[lo:hi],
            lengths=np.asarray(s.lengths)[lo:hi],
            thresh=np.asarray(s.thresh)[lo:hi],
            buf=np.asarray(s.buf)[lo:hi], sizes=np.asarray(s.sizes)[lo:hi])
        posts.append(planner.build_postings(sub))
        offs.append(lo)
    gen = planner.plan.merged_candidates(posts, offs)
    for qh, qb, qs in zip(hash_rows, bit_rows, sizes):
        want = prune.candidates_for(post_global, qh, qb, 0.5, int(qs))
        got = gen(qh, qb, 0.5, int(qs))
        np.testing.assert_array_equal(want.rec_ids, got.rec_ids)
        np.testing.assert_array_equal(want.counts, got.counts)
        np.testing.assert_array_equal(want.o1, got.o1)


def test_server_plan_hint_threshold_only(corpus, gb_index):
    from repro.serving.batcher import SketchServer

    _, _, queries = corpus
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t = [0.0]
    clock = lambda: t[0]  # noqa: E731
    out = {}
    for plan in ("pruned", "dense"):
        srv = SketchServer(gb_index, mesh, topk=0, plan=plan, max_batch=4,
                           clock=clock)
        rids = [srv.submit(q, 0.6) for q in queries[:4]]
        srv.flush()
        out[plan] = [srv.results[r] for r in rids]
    for a, b in zip(out["pruned"], out["dense"]):
        np.testing.assert_array_equal(a["hits"], b["hits"])
        assert len(a["topk_ids"]) == 0 and len(a["topk_scores"]) == 0


def test_server_topk_unchanged(corpus, gb_index):
    from repro.serving.batcher import SketchServer

    _, _, queries = corpus
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    srv = SketchServer(gb_index, mesh, topk=5, plan="pruned", max_batch=2)
    r0 = srv.submit(queries[0], 0.5)
    r1 = srv.submit(queries[1], 0.5)
    assert len(srv.results[r0]["topk_ids"]) == 5
    np.testing.assert_array_equal(
        srv.results[r0]["hits"], gb_index.query(queries[0], 0.5, plan="dense"))
    assert r1 in srv.results


# ---------------------------------------------------------------------------
# deterministic fuzz: pruning soundness on adversarial small sets
# ---------------------------------------------------------------------------


def test_pruning_sound_on_random_small_sets():
    rng = np.random.default_rng(42)
    for trial in range(8):
        m = int(rng.integers(10, 60))
        recs = [np.unique(rng.integers(0, 300, size=rng.integers(1, 30)))
                for _ in range(m)]
        total = sum(len(r) for r in recs)
        idx = api.get_engine("gbkmv").build(
            recs, max(int(total * float(rng.uniform(0.05, 0.6))), m))
        qs = [np.unique(rng.integers(0, 300, size=rng.integers(1, 25)))
              for _ in range(4)]
        for t in (0.101, 0.499, 0.93):
            dense = idx.batch_query(qs, t, plan="dense")
            pruned = idx.batch_query(qs, t, plan="pruned")
            for d, p in zip(dense, pruned):
                np.testing.assert_array_equal(d, p)
