"""Hypothesis property: the planner's candidate filter never drops a
record whose estimated containment clears the threshold (pruning bound
soundness), and the pruned path stays bit-identical to the dense sweep."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import api, planner  # noqa: E402
from repro.planner import prune  # noqa: E402

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")

id_set = st.sets(st.integers(min_value=0, max_value=500),
                 min_size=1, max_size=40)
dataset = st.lists(id_set, min_size=4, max_size=25)


@given(recs=dataset, q=id_set,
       frac=st.floats(0.05, 0.8), t=st.floats(0.05, 1.0))
def test_pruning_never_drops_a_qualifying_record(recs, q, frac, t):
    recs = [np.asarray(sorted(r)) for r in recs]
    total = sum(len(r) for r in recs)
    idx = api.get_engine("gbkmv").build(
        recs, max(int(total * frac), len(recs)))
    q = np.asarray(sorted(q))

    scores = idx.scores(q)                       # dense estimator, f32[m]
    qualifying = np.nonzero(scores >= t)[0]

    post = idx._postings()
    _, hash_rows, bit_rows, sizes = idx._plan_queries([q])
    cand = prune.candidates_for(post, hash_rows[0], bit_rows[0], float(t),
                                int(sizes[0]))
    assert set(qualifying.tolist()) <= set(cand.rec_ids.tolist())

    # End to end: verify step returns exactly the dense hit set.
    np.testing.assert_array_equal(
        idx.query(q, float(t), plan="pruned"),
        idx.query(q, float(t), plan="dense"))


@given(recs=dataset, extra=dataset, q=id_set, t=st.floats(0.1, 1.0))
def test_postings_maintenance_preserves_parity(recs, extra, q, t):
    recs = [np.asarray(sorted(r)) for r in recs]
    extra = [np.asarray(sorted(r)) for r in extra]
    total = sum(len(r) for r in recs)
    idx = api.get_engine("gbkmv").build(recs, max(int(total * 0.3), len(recs)))
    idx._postings()                              # force incremental path
    idx.insert(extra)
    assert planner.postings_equal(
        idx._post, planner.build_postings(idx.core.sketches))
    q = np.asarray(sorted(q))
    np.testing.assert_array_equal(
        idx.query(q, float(t), plan="pruned"),
        idx.query(q, float(t), plan="dense"))
