"""Block-compressed postings: encode/decode identity (deterministic fuzz
+ hypothesis property), sparse/dense block choice, incremental
maintenance on blocks, header-bound block skipping (with exact parity),
the device block-decode paths (jnp twin vs Pallas kernel vs host), and
honest arena space accounting."""

import numpy as np
import pytest

from repro import api, planner
from repro.planner import postings as P
from repro.planner import prune


def _random_csr(rng, nrows_max=14, len_max=350):
    """Random flat CSR: per-row sorted ids, duplicates allowed, mixed
    dense/sparse/empty rows — every shape the encoder must survive."""
    rows = []
    for _ in range(int(rng.integers(0, nrows_max))):
        n = int(rng.integers(0, len_max))
        style = int(rng.integers(0, 4))
        if style == 0:
            ids = np.sort(rng.integers(0, 8000, size=n))          # dups ok
        elif style == 1:
            ids = np.arange(n) + int(rng.integers(0, 64))         # dense run
        elif style == 2 and n:
            ids = np.sort(rng.choice(2**30, size=n, replace=False))
        else:
            ids = np.sort(rng.integers(0, 40, size=n))            # heavy dups
        rows.append(ids.astype(np.int64))
    offsets = np.concatenate(
        [[0], np.cumsum([len(r) for r in rows])]).astype(np.int64)
    rec = (np.concatenate(rows).astype(np.int32)
           if rows and offsets[-1] else np.zeros(0, np.int32))
    return offsets, rec


def test_encode_decode_identity_fuzz():
    rng = np.random.default_rng(0)
    for trial in range(120):
        offsets, rec = _random_csr(rng)
        st = P.encode_store(offsets, rec)
        off2, rec2 = P.decode_store(st)
        np.testing.assert_array_equal(off2, offsets, err_msg=str(trial))
        np.testing.assert_array_equal(rec2, rec, err_msg=str(trial))
        np.testing.assert_array_equal(st.row_lengths(), np.diff(offsets))
        # header invariants: first/last bracket every decoded block
        ids, cnts = P.decode_blocks(st, np.arange(st.num_blocks))
        pos = np.concatenate([[0], np.cumsum(cnts)])
        for b in range(st.num_blocks):
            seg = ids[pos[b]: pos[b + 1]]
            assert seg[0] == st.first[b] and seg[-1] == st.last[b]
            assert len(seg) <= P.BLOCK


def test_block_choice_dense_vs_sparse():
    rng = np.random.default_rng(2)
    # ~50% density, jittered: bitmap beats bitpacked deltas
    ids = np.sort(rng.choice(6000, size=3000, replace=False))
    st = P.encode_store(np.asarray([0, 3000]), ids)
    kind = (st.meta >> np.uint32(13)) & 1
    assert kind.all()
    # wide-spread ids: bitpacked deltas win and still beat flat int32
    # (≈22-bit deltas vs 32-bit ids on a 2^30 universe)
    ids2 = np.sort(rng.choice(2**30, size=3000, replace=False))
    st2 = P.encode_store(np.asarray([0, 3000]), ids2)
    assert not ((st2.meta >> np.uint32(13)) & 1).any()
    assert st2.nbytes() < 3000 * 4
    # duplicate ids (32-bit collisions) can never sit in a dense bitmap
    ids3 = np.repeat(np.arange(200), 2)
    st3 = P.encode_store(np.asarray([0, 400]), ids3)
    assert not ((st3.meta >> np.uint32(13)) & 1).any()
    _, rec3 = P.decode_store(st3)
    np.testing.assert_array_equal(rec3, ids3)


def test_blocked_truncate_append_equal_rebuild_across_boundaries():
    """Lists longer than one block keep full-block prefixes byte-stable
    through append; truncation slices keys, headers, and payload."""
    from repro.core.sketches import pack_rows

    rng = np.random.default_rng(3)

    def mkpack(rows):
        thr = np.full(len(rows), 2**32 - 2, np.uint32)
        sizes = np.full(len(rows), 5, np.int32)
        return pack_rows([np.sort(np.asarray(r, np.uint32)) for r in rows],
                         thr, sizes)

    # A shared element set forces >128-entry posting lists.
    common = rng.choice(2**31, size=7, replace=False)
    rows = [np.concatenate([common, rng.choice(2**31, size=20)])
            for _ in range(300)]
    post = P.build_postings(mkpack(rows))
    assert (post.tail.row_lengths().max()) > P.BLOCK   # multi-block lists

    rows2 = rows + [np.concatenate([common, rng.choice(2**31, size=20)])
                    for _ in range(40)]
    inc = P.append_rows(post, mkpack(rows2), 300, 340)
    fresh = P.build_postings(mkpack(rows2))
    assert planner.postings_equal(inc, fresh)

    tau = np.uint32(2**30)
    tr = P.truncate_postings(fresh, tau)
    fresh_cut = P.build_postings(mkpack(
        [np.asarray(r)[np.asarray(r) <= tau] for r in rows2]))
    assert np.array_equal(tr.keys, fresh_cut.keys)
    assert P._stores_equal(tr.tail, fresh_cut.tail)


def test_posting_lengths_from_headers():
    rng = np.random.default_rng(4)
    offsets, rec = _random_csr(rng, nrows_max=10)
    keys = np.sort(rng.choice(2**31, size=len(offsets) - 1,
                              replace=False)).astype(np.uint32)
    post = P.from_flat(keys, offsets, rec, np.zeros(1, np.int64),
                       np.zeros(0, np.int32), 8000, 2**31)
    probe = np.concatenate([keys, np.asarray([1, 2**31 - 5], np.uint32)])
    want = np.concatenate([np.diff(offsets), [0, 0]])
    np.testing.assert_array_equal(post.posting_lengths(probe), want)


# ---------------------------------------------------------------------------
# header-bound block skipping
# ---------------------------------------------------------------------------


def test_block_skipping_header_bound_exact():
    """Synthetic postings with controlled hash values: near-2³² hashes
    make unit ≈ 1, so bound_tail(c) ≈ c and the per-block keep/skip
    decision is computable by hand. Lists A/B/C overlap on one id range
    (c_max = 3 survives t = 0.6 at |Q| = 4), list D sits alone in a
    far range (c_max = 1 → ub = 0.25 < t: its block must skip and its
    records must not surface)."""
    top = np.uint32(2**32 - 10)
    keys = np.asarray([top - 3, top - 2, top - 1, top], np.uint32)
    shared = np.arange(128, dtype=np.int32)          # lists A, B, C
    alone = np.arange(5000, 5128, dtype=np.int32)    # list D
    offsets = np.asarray([0, 128, 256, 384, 512], np.int64)
    rec = np.concatenate([shared, shared, shared, alone])
    post = P.from_flat(keys, offsets, rec, np.zeros(1, np.int64),
                       np.zeros(0, np.int32), 6000, top)
    cand = prune.candidates_for(post, keys, np.zeros(0, np.int64),
                                0.6, 4)
    assert cand.skipped_blocks == 1
    assert cand.blocks == 3
    np.testing.assert_array_equal(cand.rec_ids, shared)   # D never decoded
    np.testing.assert_array_equal(cand.counts, np.full(128, 3))
    # threshold 0 decodes everything, D's records included
    cand0 = prune.candidates_for(post, keys, np.zeros(0, np.int64), 0.0, 4)
    assert cand0.skipped_blocks == 0
    np.testing.assert_array_equal(cand0.rec_ids,
                                  np.concatenate([shared, alone]))


def test_block_skipping_end_to_end_parity():
    """Two disjoint record-id clusters: whatever the header bounds skip,
    pruned results stay bit-identical to the dense sweep."""
    rng = np.random.default_rng(7)
    lo = [rng.choice(3000, size=12, replace=False) for _ in range(160)]
    hi = [3000 + rng.choice(3000, size=12, replace=False)
          for _ in range(160)]
    recs = [np.asarray(r) for r in lo + hi]
    total = sum(len(r) for r in recs)
    idx = api.get_engine("gbkmv").build(recs, int(total * 0.4),
                                        backend="numpy")
    queries = [recs[3], recs[170], np.asarray([1, 2, 3, 9, 11])]
    for t in (0.3, 0.6, 0.9):
        dense = idx.batch_query(queries, t, plan="dense")
        pruned = idx.batch_query(queries, t, plan="pruned")
        for d, p in zip(dense, pruned):
            np.testing.assert_array_equal(d, p)


# ---------------------------------------------------------------------------
# device block decode: jnp twin, Pallas kernel, dense overlay
# ---------------------------------------------------------------------------


def _device_decode_case(rng):
    offsets, rec = _random_csr(rng, nrows_max=8, len_max=300)
    st = P.encode_store(offsets, rec)
    if st.num_blocks == 0:
        return None
    kind = ((st.meta >> np.uint32(13)) & 1).astype(np.int64)
    sparse = np.nonzero(kind == 0)[0]
    if len(sparse) == 0:
        return None
    return st, sparse


def test_block_decode_jnp_matches_host():
    import jax.numpy as jnp
    from repro.kernels import postings_merge as pm

    rng = np.random.default_rng(11)
    checked = 0
    while checked < 6:
        case = _device_decode_case(rng)
        if case is None:
            continue
        st, sparse = case
        cnt = st.counts()[sparse].astype(np.int32)
        bw = ((st.meta[sparse] >> np.uint32(8)) & np.uint32(0x1F)
              ).astype(np.int32)
        pay = jnp.asarray(np.concatenate(
            [st.payload, np.zeros(pm.DECODE_WINDOW, np.uint32)]))
        got = np.asarray(pm._decode_sparse_jnp(
            jnp.asarray(st.first[sparse]),
            jnp.asarray(st.off[sparse], jnp.int32),
            jnp.asarray(bw), jnp.asarray(cnt), pay))
        want_ids, want_cnt = P.decode_blocks(st, sparse)
        pos = np.concatenate([[0], np.cumsum(want_cnt)])
        for j in range(len(sparse)):
            np.testing.assert_array_equal(
                got[j, : int(want_cnt[j])], want_ids[pos[j]: pos[j + 1]])
        checked += 1


def test_block_decode_pallas_kernel_matches_jnp():
    """The Pallas block-decode kernel (interpret mode) is lane-for-lane
    identical to the jnp twin on real encoded stores."""
    import jax.numpy as jnp
    from repro.kernels import postings_merge as pm

    rng = np.random.default_rng(13)
    case = None
    while case is None:
        case = _device_decode_case(rng)
    st, sparse = case
    cnt = st.counts()[sparse].astype(np.int32)
    bw = ((st.meta[sparse] >> np.uint32(8)) & np.uint32(0x1F)
          ).astype(np.int32)
    pay = jnp.asarray(np.concatenate(
        [st.payload, np.zeros(pm.DECODE_WINDOW, np.uint32)]))
    first = jnp.asarray(st.first[sparse])
    off = jnp.asarray(st.off[sparse], jnp.int32)
    a = np.asarray(pm._decode_sparse_jnp(first, off, jnp.asarray(bw),
                                         jnp.asarray(cnt), pay))
    b = np.asarray(pm._decode_sparse_pallas(first, off, jnp.asarray(bw),
                                            jnp.asarray(cnt), pay,
                                            interpret=True))
    lanes = np.arange(P.BLOCK)[None, :]
    valid = lanes < cnt[:, None]
    np.testing.assert_array_equal(a[valid], b[valid])


@pytest.mark.parametrize("backend", ("jnp", "pallas"))
def test_device_dense_block_overlay_parity(backend):
    """Indexes whose postings contain dense-bitmap blocks answer
    device-pruned queries bit-identically to the dense sweep (the tbd
    overlay path)."""
    rng = np.random.default_rng(17)
    share = set(np.sort(rng.choice(1200, size=660, replace=False)).tolist())
    recs = [np.asarray([3000 + i] + ([7] if i in share else []))
            for i in range(1200)]
    idx = api.get_engine("gbkmv").build(recs, budget=5000, backend=backend,
                                        r=0)
    kind = (idx._postings().tail.meta >> np.uint32(13)) & 1
    assert int(kind.sum()) > 0                 # dense blocks really exist
    q = np.asarray([7, 99991, 99992])
    dense = idx.batch_query([q], 0.2, plan="dense")[0]
    pruned = idx.batch_query([q], 0.2, plan="pruned")[0]
    assert idx.last_plan.tail_dense_blocks > 0
    np.testing.assert_array_equal(dense, pruned)


# ---------------------------------------------------------------------------
# honest space accounting
# ---------------------------------------------------------------------------


def test_arena_nbytes_counts_postings_and_mirrors():
    rng = np.random.default_rng(19)
    recs = [rng.choice(5000, size=30, replace=False) for _ in range(150)]
    idx = api.get_engine("gbkmv").build(recs, budget=2000, backend="jnp")
    arena = idx._sketch_pack()
    base = arena.sketch_nbytes()
    assert idx.nbytes() == base                 # nothing derived yet
    post_b = arena.postings_nbytes()            # builds the postings
    assert post_b > 0
    assert idx.nbytes() == base + post_b
    idx.batch_query([recs[0]], 0.5, plan="pruned")  # device mirrors placed
    total = idx.nbytes()
    assert total > base + post_b
    dev = arena.device_postings().nbytes() + arena.device_pack().nbytes()
    assert total == base + post_b + dev
    # The device mirror ships only the tail store — strictly less than
    # the at-rest postings (buffer lists never cross to the device).
    assert arena.device_postings().nbytes() < post_b


