"""Hypothesis property: block decode is the identity on random postings
— for ANY per-row sorted id lists (duplicates included), decode(encode)
returns the exact flat CSR, so the blocked store is information-lossless
by construction, not just on the workloads we benchmarked."""

import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property test needs hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.planner import postings as P  # noqa: E402

settings.register_profile("blocks", max_examples=40, deadline=None)
settings.load_profile("blocks")


@given(st.lists(
    st.lists(st.integers(min_value=0, max_value=2**31 - 1),
             min_size=0, max_size=300),
    min_size=0, max_size=8))
def test_block_decode_is_identity_property(rows):
    rows = [np.sort(np.asarray(r, np.int64)) for r in rows]
    offsets = np.concatenate(
        [[0], np.cumsum([len(r) for r in rows])]).astype(np.int64)
    rec = (np.concatenate(rows).astype(np.int32)
           if rows and offsets[-1] else np.zeros(0, np.int32))
    store = P.encode_store(offsets, rec)
    off2, rec2 = P.decode_store(store)
    np.testing.assert_array_equal(off2, offsets)
    np.testing.assert_array_equal(rec2, rec)


@given(st.lists(st.integers(min_value=0, max_value=2**20), min_size=0,
                max_size=220),
       st.integers(min_value=0, max_value=2**20))
def test_truncate_is_prefix_of_keys_property(key_list, tau):
    """Truncation at any τ equals rebuilding from only the ≤ τ keys."""
    keys = np.unique(np.asarray(key_list, np.uint32))
    offsets = np.arange(len(keys) + 1, dtype=np.int64)   # one id per key
    rec = np.arange(len(keys), dtype=np.int32)
    post = P.from_flat(keys, offsets, rec, np.zeros(1, np.int64),
                       np.zeros(0, np.int32), len(keys) or 1,
                       keys[-1] if len(keys) else 0)
    tr = P.truncate_postings(post, np.uint32(tau))
    cut = int(np.searchsorted(keys, np.uint32(tau), side="right"))
    fresh = P.from_flat(keys[:cut], offsets[: cut + 1], rec[:cut],
                        np.zeros(1, np.int64), np.zeros(0, np.int32),
                        post.num_records, tau)
    assert P.postings_equal(tr, fresh)
