"""Hypothesis property tests on the system's invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import gkmv
from repro.core.estimators import (
    gkmv_pair_estimate, gkmv_pair_oracle_np, buffer_intersection,
)
from repro.core.hashing import hash_u32_np, PAD
from repro.core.search import f_score

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

id_sets = st.sets(st.integers(min_value=0, max_value=4000), min_size=2, max_size=120)


def _sketch(ids, tau):
    h = np.sort(hash_u32_np(np.asarray(sorted(ids))))
    return h[h <= tau]


@given(q=id_sets, x=id_sets,
       tq=st.floats(0.05, 0.9), tx=st.floats(0.05, 0.9))
def test_gkmv_pair_equals_oracle(q, x, tq, tx):
    """The packed vectorized estimator == set-based oracle, ∀ inputs."""
    tq32, tx32 = np.uint32(tq * 2**32), np.uint32(tx * 2**32)
    lq, lx = _sketch(q, tq32), _sketch(x, tx32)
    cap = max(len(lq), len(lx), 1)
    qv = np.full(cap, PAD, np.uint32); qv[: len(lq)] = lq
    xv = np.full((1, cap), PAD, np.uint32); xv[0, : len(lx)] = lx
    d, k, kc = gkmv_pair_estimate(
        jnp.asarray(qv), jnp.int32(len(lq)), jnp.uint32(tq32),
        jnp.asarray(xv), jnp.asarray([len(lx)], np.int32),
        jnp.asarray([tx32], np.uint32))
    od, ok, okc = gkmv_pair_oracle_np(lq, tq32, lx, tx32)
    assert int(k[0]) == ok
    assert int(kc[0]) == okc
    np.testing.assert_allclose(float(d[0]), od, rtol=3e-5, atol=1e-6)


@given(q=id_sets, x=id_sets, t=st.floats(0.05, 0.95))
def test_kcap_bounded_by_true_intersection(q, x, t):
    """K∩ counts common hash values — never exceeds |Q∩X| (no collisions)."""
    t32 = np.uint32(t * 2**32)
    lq, lx = _sketch(q, t32), _sketch(x, t32)
    _, _, okc = gkmv_pair_oracle_np(lq, t32, lx, t32)
    assert okc <= len(q & x)


@given(rows=st.lists(id_sets, min_size=2, max_size=10),
       frac=st.floats(0.1, 0.9))
def test_threshold_budget_never_exceeded(rows, frac):
    hrows = [hash_u32_np(np.asarray(sorted(r))) for r in rows]
    total = sum(len(r) for r in hrows)
    budget = max(int(frac * total), 1)
    tau = gkmv.select_global_threshold(hrows, budget)
    kept = sum(int((r <= tau).sum()) for r in hrows)
    # Identical elements in different records share one hash: τ cannot split
    # ties, so the budget may be exceeded only by the tie multiplicity at τ.
    ties = sum(int((r == tau).sum()) for r in hrows)
    assert kept <= max(budget, 1) + max(ties - 1, 0) or tau == np.uint32(PAD - 1)


@given(st.lists(st.integers(0, 63), min_size=0, max_size=40),
       st.lists(st.integers(0, 63), min_size=0, max_size=40))
def test_popcount_matches_set_intersection(a_bits, b_bits):
    def bm(bits):
        w = np.zeros(2, np.uint32)
        for b in bits:
            w[b // 32] |= np.uint32(1) << np.uint32(b % 32)
        return w
    got = int(buffer_intersection(jnp.asarray(bm(a_bits)),
                                  jnp.asarray(bm(b_bits))[None, :])[0])
    assert got == len(set(a_bits) & set(b_bits))


@given(t=st.sets(st.integers(0, 50), max_size=20),
       a=st.sets(st.integers(0, 50), max_size=20))
def test_f_score_bounds_and_perfect(t, a):
    f = f_score(np.asarray(sorted(t)), np.asarray(sorted(a)))
    assert 0.0 <= f <= 1.0
    if t == a:
        assert f == 1.0
