"""Extra hypothesis property tests: system invariants of the sketch
index, EmbeddingBag substrate, and checkpoint layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.gbkmv import build_gbkmv, sketch_query
from repro.core.estimators import gbkmv_containment
from repro.core.hashing import hash_u32_np
from repro.models.embedding import embedding_bag

SETS = st.lists(
    st.lists(st.integers(0, 2000), min_size=3, max_size=60,
             unique=True).map(lambda x: np.asarray(sorted(x), np.int64)),
    min_size=3, max_size=15)


@settings(max_examples=20, deadline=None)
@given(SETS, st.integers(1, 8), st.integers(0, 64))
def test_device_scores_match_set_oracle(records, budget_per_rec, r):
    """The vectorized device estimator must agree with the paper-formula
    set oracle on EVERY (query=record, record) pair — including the
    degenerate tiny-sketch cases hypothesis loves (the estimator is
    legitimately noisy there, but it must be *consistently* noisy)."""
    from repro.core.estimators import gkmv_pair_oracle_np

    budget = budget_per_rec * len(records)
    index = build_gbkmv(records, budget=budget, r=r)
    s = index.sketches
    for i, rec in enumerate(records):
        q = sketch_query(index, rec)
        scores = np.asarray(gbkmv_containment(q, index.sketches))
        qh = np.asarray(q.values[0][: int(q.lengths[0])])
        for j in range(len(records)):
            xh = np.asarray(s.values[j][: int(s.lengths[j])])
            d_hat, _, _ = gkmv_pair_oracle_np(
                qh, int(q.thresh[0]), xh, int(s.thresh[j]))
            buf_inter = bin(int.from_bytes(
                (np.asarray(q.buf[0]) & np.asarray(s.buf[j])).tobytes(),
                "little")).count("1") if s.buf.shape[1] else 0
            expect = (buf_inter + d_hat) / max(len(rec), 1)
            np.testing.assert_allclose(scores[j], expect, rtol=1e-5,
                                       atol=1e-5, err_msg=f"pair ({i},{j})")


@settings(max_examples=20, deadline=None)
@given(SETS)
def test_budget_monotone_threshold(records):
    """A larger budget never LOWERS the global threshold τ (more hashes
    kept per record → strictly more information)."""
    taus = []
    for frac in (2, 4, 8):
        budget = frac * len(records)
        index = build_gbkmv(records, budget=budget, r=0)
        taus.append(int(index.tau))
    assert taus[0] <= taus[1] <= taus[2]


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 40), st.integers(2, 30), st.integers(1, 6),
       st.sampled_from(["sum", "mean", "max"]))
def test_embedding_bag_matches_loop(n_rows, nnz, n_bags, combiner):
    """take+segment_sum EmbeddingBag == per-bag python loop oracle."""
    rng = np.random.default_rng(n_rows * 31 + nnz)
    table = jnp.asarray(rng.normal(size=(n_rows, 5)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, n_rows, nnz), jnp.int32)
    seg = jnp.asarray(np.sort(rng.integers(0, n_bags, nnz)), jnp.int32)
    out = np.asarray(embedding_bag(table, idx, seg, n_bags, combiner))
    t = np.asarray(table)
    for b in range(n_bags):
        rows = t[np.asarray(idx)[np.asarray(seg) == b]]
        if len(rows) == 0:
            expect = np.zeros(5) if combiner != "max" else out[b]
        elif combiner == "sum":
            expect = rows.sum(0)
        elif combiner == "mean":
            expect = rows.mean(0)
        else:
            expect = rows.max(0)
        np.testing.assert_allclose(out[b], expect, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(1, 200))
def test_hash_jnp_matches_np(seed, n):
    ids = np.arange(n, dtype=np.int64) * 7 + seed % 1000
    from repro.core.hashing import hash_u32
    np.testing.assert_array_equal(
        np.asarray(hash_u32(jnp.asarray(ids), seed=seed % 97)),
        hash_u32_np(ids, seed=seed % 97))


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 10_000), min_size=10, max_size=300,
                unique=True))
def test_gkmv_union_is_valid_kmv(elems):
    """Theorem 2 property: every hash in the τ-filtered sketch is ≤ τ and
    the sketch contains ALL element hashes below τ (no gaps)."""
    rec = np.asarray(sorted(elems), np.int64)
    index = build_gbkmv([rec, rec[: len(rec) // 2]], budget=20, r=0)
    s = index.sketches
    h = np.sort(hash_u32_np(rec))
    kept = np.asarray(s.values[0][: int(s.lengths[0])])
    tau_eff = int(s.thresh[0])
    expected = h[h <= tau_eff]
    np.testing.assert_array_equal(kept, expected)


def test_checkpoint_property_roundtrip(tmp_path):
    """Random pytrees of mixed dtypes survive save→restore bit-exactly."""
    from repro.ft import checkpoint as ckpt

    rng = np.random.default_rng(0)
    for trial in range(5):
        tree = {
            "a": jnp.asarray(rng.normal(size=(3, 4)), jnp.bfloat16),
            "b": {"c": jnp.asarray(rng.integers(0, 100, 7), jnp.int32),
                  "d": [jnp.float32(rng.normal()),
                        jnp.asarray(rng.random(2), jnp.float16)]},
        }
        d = str(tmp_path / f"ck{trial}")
        ckpt.save_checkpoint(d, trial, tree)
        restored, _ = ckpt.restore_checkpoint(d, target=tree)
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x, np.float64),
                                          np.asarray(y, np.float64))
