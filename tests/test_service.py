"""Service-layer tests: histogram/stats primitives, the async flush loop
under an injectable clock (no threads — fully deterministic), middleware,
metrics rendering, and live in-process HTTP round-trips asserting the
service answers bit-identically to the direct index calls."""

import http.client
import json
import math
import socket
import threading

import numpy as np
import pytest

from repro.serving import BatchStats, Histogram
from repro.service import (
    AsyncSketchServer, AuthToken, Overloaded, ServiceApp, ServiceClient,
    ServiceError, ServiceHandle, TokenBucket, parse_prometheus)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class StubIndex:
    """Minimal serve_batch/insert protocol with a call log, so flush
    ordering and plan selection are observable without jax."""

    def __init__(self):
        self.records = [np.arange(5)]
        self.log = []                   # ("serve", n, plan) | ("insert", n)

    @property
    def num_records(self):
        return len(self.records)

    def serve_batch(self, queries, thresholds, k, plan="auto"):
        self.log.append(("serve", len(queries), plan))
        thresholds = np.broadcast_to(np.asarray(thresholds), (len(queries),))
        out = []
        for q, t in zip(queries, thresholds):
            hits = (np.asarray([], np.int64) if math.isinf(t)
                    else np.asarray(sorted(np.asarray(q).tolist())[:2]))
            out.append({"hits": hits,
                        "topk_ids": np.arange(k, dtype=np.int64),
                        "topk_scores": np.linspace(1.0, 0.5, max(k, 1),
                                                   dtype=np.float32)})
        return out

    def insert(self, records):
        self.log.append(("insert", len(records)))
        self.records.extend(records)


def make_server(**kw):
    clk = FakeClock()
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_wait", 0.01)
    srv = AsyncSketchServer(StubIndex(), clock=clk, **kw)
    return srv, srv.index, clk


# -- histogram / stats primitives -------------------------------------------


def test_histogram_observe_and_quantile():
    h = Histogram(bounds=[0.1, 1.0, 10.0])
    h.observe_many([0.05] * 50 + [0.5] * 50)
    assert h.count == 100 and h.sum == pytest.approx(27.5)
    # p25 sits mid-first-bucket, p75 mid-second (linear interpolation).
    assert h.quantile(0.25) == pytest.approx(0.05)
    assert h.quantile(0.75) == pytest.approx(0.55)
    h.observe(100.0)                    # overflow bucket clamps to last bound
    assert h.quantile(1.0) == pytest.approx(10.0)
    assert h.mean == pytest.approx(127.5 / 101)


def test_histogram_merge_and_prometheus_text():
    a, b = Histogram(bounds=[1.0, 2.0]), Histogram(bounds=[1.0, 2.0])
    a.observe(0.5)
    b.observe(1.5)
    b.observe(99.0)
    a.merge(b)
    lines = a.to_prometheus("lat", 'kind="q"')
    assert 'lat_bucket{kind="q",le="1"} 1' in lines
    assert 'lat_bucket{kind="q",le="2"} 2' in lines
    assert 'lat_bucket{kind="q",le="+Inf"} 3' in lines
    assert any(ln.startswith('lat_count{kind="q"} 3') for ln in lines)
    with pytest.raises(ValueError):
        a.merge(Histogram(bounds=[5.0]))


def test_batch_stats_reasons_and_wait_histogram():
    s = BatchStats()
    s.record_batch([0.001, 0.002], "full")
    s.record_batch([0.010], "deadline")
    s.record_batch([3.0], "expired")
    assert (s.flushes_full, s.flushes_deadline, s.flushes_expired) == (1, 1, 1)
    assert s.flushes == 3 and s.served == 4
    assert s.mean_batch == pytest.approx(4 / 3)
    assert s.queue_wait_hist.count == 4
    assert s.queue_wait_hist.quantile(0.99) > 1.0
    # Ingest flushes count separately and never skew device occupancy.
    s.record_batch([0.5, 0.5, 0.5], "ingest")
    assert s.flushes_ingest == 1 and s.flushes == 3
    assert s.mean_batch == pytest.approx(4 / 3)
    assert s.queue_wait_hist.count == 7 and s.served == 7


def test_histogram_snapshot_consistent_under_concurrent_writes():
    """A /metrics scrape must never see counts torn against sum."""
    h = Histogram(bounds=[1.0, 2.0])
    stop = threading.Event()

    def writer():
        while not stop.is_set():
            h.observe(1.5)

    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(300):
            counts, total = h.snapshot()
            assert total == pytest.approx(int(counts.sum()) * 1.5)
    finally:
        stop.set()
        t.join()


# -- async flush loop (deterministic: fake clock, no worker thread) ---------


def test_async_server_flush_on_full_then_deadline():
    srv, stub, clk = make_server()
    p1 = srv.submit_query(np.arange(4), threshold=0.5)
    p2 = srv.submit_query(np.arange(8), threshold=0.5)
    p3 = srv.submit_query(np.arange(2), threshold=0.5)
    assert srv.inflight == 3
    assert srv.step() == 2              # full batch pops immediately
    assert srv.stats.flushes_full == 1
    assert p1.done.is_set() and p2.done.is_set() and not p3.done.is_set()
    assert srv.step() == 0              # straggler not old enough
    clk.t += 0.02
    assert srv.step() == 1              # aged past max_wait → deadline flush
    assert srv.stats.flushes_deadline == 1 and p3.done.is_set()
    np.testing.assert_array_equal(srv.result(p1, timeout=0)["hits"], [0, 1])
    assert srv.inflight == 0


def test_async_server_expired_requests_take_dense_fallback():
    srv, stub, clk = make_server(max_wait=10.0, default_deadline=1.0)
    p = srv.submit_query(np.arange(6), threshold=0.5)
    assert srv.step() == 0              # young: neither full nor expired
    clk.t += 2.0                        # now past its deadline
    assert srv.step() == 1
    assert p.expired and srv.expired_served == 1
    assert srv.stats.flushes_expired == 1
    assert stub.log == [("serve", 1, "dense")]
    np.testing.assert_array_equal(srv.result(p, timeout=0)["hits"], [0, 1])


def test_async_server_overload_sheds_with_retry_hint():
    srv, _, _ = make_server(max_inflight=2, max_wait=10.0)
    srv.submit_query(np.arange(3))
    srv.submit_query(np.arange(3))
    with pytest.raises(Overloaded) as ei:
        srv.submit_query(np.arange(3))
    assert ei.value.retry_after > 0
    assert srv.shed == 1 and srv.inflight == 2


def test_async_server_ingest_is_a_fifo_barrier():
    srv, stub, clk = make_server(max_batch=4)
    q1 = srv.submit_query(np.arange(3))
    ing = srv.submit_ingest([np.arange(10, 14), np.arange(20, 26)])
    q2 = srv.submit_query(np.arange(3))
    srv.drain()
    # Kinds never mix: serve(q1) → insert → serve(q2), in admission order.
    assert stub.log == [("serve", 1, srv.plan), ("insert", 2),
                        ("serve", 1, srv.plan)]
    assert srv.result(ing, timeout=0) == {"ingested": 2}
    assert srv.records_ingested == 2 and stub.num_records == 3
    assert q1.done.is_set() and q2.done.is_set()
    # Ingest accounting stays off the device-flush metrics: two serve
    # flushes in flush_latency_hist, the insert in ingest_latency_hist.
    assert srv.stats.flushes_ingest == 1 and srv.stats.flushes == 2
    assert srv.stats.flush_latency_hist.count == 2
    assert srv.stats.ingest_latency_hist.count == 1


def test_concurrent_submissions_mint_unique_rids():
    """HTTP handler threads submit concurrently; duplicate rids would
    hand two requests each other's results via the execute_batch map."""
    srv = AsyncSketchServer(StubIndex(), max_batch=64, max_wait=10.0,
                            max_inflight=10_000)
    pendings, lock = [], threading.Lock()

    def submit_many():
        mine = [srv.submit_query(np.arange(3)) for _ in range(200)]
        with lock:
            pendings.extend(mine)

    threads = [threading.Thread(target=submit_many) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    rids = [p.rid for p in pendings]
    assert len(rids) == 1600 and len(set(rids)) == 1600


def test_async_server_mixed_topk_and_query_batch():
    srv, stub, clk = make_server(max_batch=4, max_wait=0.0)
    q = srv.submit_query(np.arange(4), threshold=0.5)
    t = srv.submit_topk(np.arange(4), k=3)
    assert srv.step() == 2              # one batch, max_wait=0 flushes now
    assert stub.log == [("serve", 2, srv.plan)]
    assert srv.result(q, timeout=0)["hits"].size == 2
    res = srv.result(t, timeout=0)
    assert len(res["topk_ids"]) == 3    # truncated to the request's k
    assert t.threshold == math.inf      # topk never contributes hits


def test_async_server_worker_thread_round_trip():
    srv = AsyncSketchServer(StubIndex(), max_batch=4, max_wait=0.002)
    srv.start()
    try:
        p = srv.submit_query(np.arange(5), threshold=0.5)
        np.testing.assert_array_equal(srv.result(p)["hits"], [0, 1])
    finally:
        srv.stop()


# -- middleware -------------------------------------------------------------


def test_token_bucket_rate_and_refill():
    clk = FakeClock()
    b = TokenBucket(rate=2.0, burst=2, clock=clk)
    assert b.allow() and b.allow() and not b.allow()
    assert b.retry_after() > 0
    clk.t += 0.5                        # refills one token at 2/s
    assert b.allow() and not b.allow()
    assert TokenBucket(rate=None).allow()   # disabled bucket always allows


def test_auth_token_header_forms():
    auth = AuthToken("s3cret")
    assert auth.allows({"Authorization": "Bearer s3cret"})
    assert auth.allows({"X-Auth-Token": "s3cret"})
    assert not auth.allows({"Authorization": "Bearer wrong"})
    assert not auth.allows({})
    assert AuthToken(None).allows({})   # auth disabled


def test_metrics_render_parse_round_trip():
    from repro.service import Metrics
    m = Metrics()
    m.inc("req_total", {"endpoint": "query", "status": "200"}, help="reqs")
    m.inc("req_total", {"endpoint": "query", "status": "200"})
    m.set_gauge("depth", lambda: 7, help="live gauge")
    m.observe("lat_seconds", 0.005, {"endpoint": "query"})
    text = m.render()
    assert "# TYPE req_total counter" in text
    assert "# TYPE lat_seconds histogram" in text
    pm = parse_prometheus(text)
    assert pm['req_total{endpoint="query",status="200"}'] == 2.0
    assert pm["depth"] == 7.0
    assert pm['lat_seconds_count{endpoint="query"}'] == 1.0
    assert m.get_counter("req_total",
                         {"endpoint": "query", "status": "200"}) == 2


# -- live HTTP (stub index: no jax in the hot path) -------------------------


def serve_stub(**app_kw):
    srv = AsyncSketchServer(StubIndex(), max_batch=4, max_wait=0.002)
    return ServiceHandle(ServiceApp(srv, **app_kw))


def test_http_auth_rejection_and_success():
    with serve_stub(auth_token="hunter2") as h:
        anon = ServiceClient(*h.address)
        assert anon.healthz()["status"] == "ok"       # healthz stays open
        with pytest.raises(ServiceError) as ei:
            anon.query(np.arange(3), 0.5)
        assert ei.value.status == 401
        authed = ServiceClient(*h.address, token="hunter2")
        np.testing.assert_array_equal(authed.query(np.arange(3), 0.5), [0, 1])
        anon.close(), authed.close()


def test_http_rate_limit_429():
    with serve_stub(rate_limit=1e-6, burst=2) as h:
        cli = ServiceClient(*h.address)
        cli.query(np.arange(3), 0.5)
        cli.query(np.arange(3), 0.5)    # burst exhausted
        with pytest.raises(ServiceError) as ei:
            cli.query(np.arange(3), 0.5)
        assert ei.value.status == 429 and ei.value.retry_after > 0
        cli.close()


def test_http_overload_shed_429_and_metric():
    with serve_stub() as h:
        h.app.server.max_inflight = 0   # every admission sheds
        cli = ServiceClient(*h.address)
        with pytest.raises(ServiceError) as ei:
            cli.query(np.arange(3), 0.5)
        assert ei.value.status == 429 and ei.value.retry_after > 0
        pm = parse_prometheus(cli.metrics_text())
        assert pm["service_shed_total"] >= 1.0
        assert pm['service_requests_total{endpoint="query",status="429"}'] == 1
        cli.close()


def test_http_routing_errors():
    with serve_stub() as h:
        cli = ServiceClient(*h.address)
        status, _, _ = cli.request("GET", "/nope")
        assert status == 404
        status, _, _ = cli.request("GET", "/query")
        assert status == 405
        status, body, _ = cli.request("POST", "/query", body=b"not json")
        assert status == 400 and b"bad request" in body
        cli.close()


def test_http_early_error_drains_body_for_keepalive():
    """401/404/429 answer before reading the POST body; the unread bytes
    must be drained or they'd be parsed as the next request line on the
    persistent connection."""
    with serve_stub(auth_token="tok") as h:
        conn = http.client.HTTPConnection(*h.address, timeout=10)
        body = json.dumps({"q": list(range(500))}).encode()
        for path, want in (("/query", 401), ("/nope", 404), ("/query", 401)):
            conn.request("POST", path, body=body,
                         headers={"Content-Type": "application/json"})
            r = conn.getresponse()
            assert r.status == want
            r.read()
        # Same connection, no reconnect: still a clean request stream.
        conn.request("GET", "/healthz")
        r = conn.getresponse()
        assert r.status == 200 and json.loads(r.read())["status"] == "ok"
        conn.close()


def test_http_rate_limited_connection_stays_usable():
    with serve_stub(rate_limit=1e-6, burst=1) as h:
        conn = http.client.HTTPConnection(*h.address, timeout=10)
        payload = json.dumps({"q": [0, 1, 2]}).encode()
        hdrs = {"Content-Type": "application/json"}
        conn.request("POST", "/query", body=payload, headers=hdrs)
        assert conn.getresponse().read() is not None    # burst spent
        conn.request("POST", "/query", body=payload, headers=hdrs)
        r = conn.getresponse()
        assert r.status == 429 and float(r.getheader("Retry-After")) > 0
        r.read()
        conn.request("GET", "/healthz")                 # same connection
        r = conn.getresponse()
        assert r.status == 200 and json.loads(r.read())["status"] == "ok"
        conn.close()


def test_http_chunked_extensions_and_trailers():
    """Chunk-size lines with long extensions and trailer headers after
    the last chunk are valid chunked framing and must decode."""
    with serve_stub(ingest_chunk=8) as h:
        rec = json.dumps([1, 2, 3]).encode() + b"\n"
        ext = b";name=" + b"x" * 200            # size line far beyond 64B
        chunked = ((b"%x" % len(rec)) + ext + b"\r\n" + rec + b"\r\n"
                   + b"0\r\nx-trailer: v\r\n\r\n")
        req = (b"POST /ingest HTTP/1.1\r\nHost: t\r\n"
               b"Content-Type: application/x-ndjson\r\n"
               b"Transfer-Encoding: chunked\r\n\r\n" + chunked)
        with socket.create_connection(h.address, timeout=10) as s:
            s.sendall(req)
            r = http.client.HTTPResponse(s)
            r.begin()
            assert r.status == 200
            assert json.loads(r.read()) == {"ingested": 1, "chunks": 1}


def test_http_streaming_ingest_chunks():
    with serve_stub(ingest_chunk=2) as h:
        cli = ServiceClient(*h.address)
        out = cli.ingest([np.arange(i, i + 4) for i in range(5)])
        assert out == {"ingested": 5, "chunks": 3}    # 2+2+1 flush chunks
        assert cli.healthz()["records"] == 6          # stub started with 1
        out = cli.ingest([np.arange(3)], stream=False)
        assert out == {"ingested": 1, "chunks": 1}
        pm = parse_prometheus(cli.metrics_text())
        assert pm["service_records_ingested_total"] == 6.0
        cli.close()


# -- live HTTP against the real index: bit-identical parity -----------------


@pytest.fixture(scope="module")
def live_service():
    from repro import api
    from repro.data.synth import generate_dataset, make_query_workload
    from repro.launch.mesh import make_mesh
    from repro.sketchindex import ShardedIndex

    recs = generate_dataset(m=100, n_elems=3000, alpha_freq=1.1,
                            alpha_size=2.0, seed=0)
    index = api.get_engine("gbkmv").build(
        recs, sum(len(r) for r in recs) // 5)
    sharded = ShardedIndex(index, make_mesh((1, 1), ("data", "model")))
    srv = AsyncSketchServer(sharded, max_batch=4, max_wait=0.002)
    with ServiceHandle(ServiceApp(srv)) as h:
        yield h, sharded, make_query_workload(recs, 8, seed=1)


def test_http_query_parity_with_direct(live_service):
    h, sharded, queries = live_service
    cli = ServiceClient(*h.address)
    direct = sharded.batch_query(queries, 0.5)
    for q, d in zip(queries, direct):
        np.testing.assert_array_equal(cli.query(q, 0.5), d)
    cli.close()


def test_http_topk_parity_with_direct(live_service):
    h, sharded, queries = live_service
    cli = ServiceClient(*h.address)
    for q in queries[:4]:
        ids, scores = cli.topk(q, 5)
        d_ids, d_scores = sharded.topk(q, 5)
        np.testing.assert_array_equal(ids, d_ids)
        np.testing.assert_array_equal(scores, d_scores.astype(np.float32))
    cli.close()


def test_http_ingest_then_query_sees_new_record(live_service):
    h, sharded, _ = live_service
    cli = ServiceClient(*h.address)
    before = cli.healthz()["records"]
    new = np.arange(9000, 9040)
    assert cli.ingest([new]) == {"ingested": 1, "chunks": 1}
    assert cli.healthz()["records"] == before + 1
    # The new record contains itself; with the tight test budget the KMV
    # estimate is well under 1, so probe at a low threshold. The load-
    # bearing assertion is parity: HTTP == direct on the mutated index.
    hits = cli.query(new, 0.2)
    assert before in hits.tolist()      # its id == old record count
    np.testing.assert_array_equal(hits, sharded.batch_query([new], 0.2)[0])
    cli.close()


def test_http_metrics_shape(live_service):
    h, _, _ = live_service
    cli = ServiceClient(*h.address)
    pm = parse_prometheus(cli.metrics_text())
    for key in ("service_flush_total{reason=\"full\"}",
                "service_queue_wait_seconds_count",
                "service_flush_latency_seconds_sum",
                "service_mean_batch_occupancy", "service_inflight",
                "arena_sketch_nbytes"):
        assert key in pm, key
    assert pm["arena_sketch_nbytes"] > 0
    assert pm["service_queue_wait_seconds_count"] >= 1
    cli.close()
