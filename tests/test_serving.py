"""Serving batcher tests: size/deadline flush semantics with a fake
clock; end-to-end SketchServer results == direct index search."""

import jax
import numpy as np

from repro.core.gbkmv import build_gbkmv, search
from repro.data.synth import generate_dataset, make_query_workload
from repro.serving import MicroBatcher, Request, SketchServer


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_batcher_flush_on_size():
    clk = FakeClock()
    b = MicroBatcher(max_batch=3, max_wait=1.0, clock=clk)
    assert b.submit(Request(0, np.arange(3), clk())) is None
    assert b.submit(Request(1, np.arange(3), clk())) is None
    batch = b.submit(Request(2, np.arange(3), clk()))
    assert batch is not None and len(batch) == 3
    assert b.stats.flushes_full == 1 and not b.pending


def test_batcher_flush_on_deadline():
    clk = FakeClock()
    b = MicroBatcher(max_batch=8, max_wait=0.5, clock=clk)
    b.submit(Request(0, np.arange(3), clk()))
    assert b.poll() is None            # not old enough
    clk.t = 0.6
    batch = b.poll()
    assert batch is not None and len(batch) == 1
    assert b.stats.flushes_deadline == 1
    assert b.stats.mean_wait > 0.5


def test_sketch_server_end_to_end():
    recs = generate_dataset(m=120, n_elems=4000, alpha_freq=1.1,
                            alpha_size=2.0, seed=0)
    index = build_gbkmv(recs, budget=2500, r=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    clk = FakeClock()
    srv = SketchServer(index, mesh, max_batch=4, max_wait=0.1, topk=5,
                       clock=clk)
    queries = make_query_workload(recs, 6)
    rids = [srv.submit(q, threshold=0.5) for q in queries]
    srv.flush()                         # drain the 2 stragglers
    assert set(rids) <= set(srv.results)
    for rid, q in zip(rids, queries):
        res = srv.results[rid]
        direct = set(search(index, q, 0.5).tolist())
        assert set(res["hits"].tolist()) == direct
        assert res["topk_scores"].shape == (5,)
        # top-k scores are sorted descending
        assert all(a >= b for a, b in
                   zip(res["topk_scores"], res["topk_scores"][1:]))
    assert srv.batcher.stats.flushes_full == 1
    assert srv.batcher.stats.flushes_deadline == 1
