"""Distributed sketch-index tests: device scoring vs host oracle, global
top-k vs numpy, histogram τ vs exact quantile, query batching.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gbkmv as gbkmv_mod
from repro.core.gbkmv import build_gbkmv, sketch_query
from repro.core.hashing import hash_u32_np
from repro.data.synth import generate_dataset, make_query_workload
from repro.sketchindex import (
    batch_queries,
    distributed_tau,
    distributed_topk,
    score_batch,
    to_device_index,
)
from repro.sketchindex.build import histogram_tau


def _setup(m=150, budget=3000, r=64, seed=0):
    recs = generate_dataset(m=m, n_elems=4000, alpha_freq=1.1,
                            alpha_size=2.0, seed=seed)
    idx = build_gbkmv(recs, budget=budget, r=r, seed=seed)
    return recs, idx


def test_device_scores_match_host():
    recs, idx = _setup()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    didx = to_device_index(idx, mesh)
    queries = make_query_workload(recs, 6)
    qp = batch_queries(idx, queries)
    scores = np.asarray(score_batch(didx, qp))
    for j, q in enumerate(queries):
        host = np.asarray(gbkmv_mod.containment_scores(idx, sketch_query(idx, q)))
        np.testing.assert_allclose(scores[: idx.num_records, j], host,
                                   rtol=1e-5, atol=1e-5)


def test_kernel_impl_matches_jnp():
    recs, idx = _setup(m=64)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    didx = to_device_index(idx, mesh)
    qp = batch_queries(idx, make_query_workload(recs, 3))
    s_jnp = np.asarray(score_batch(didx, qp, backend="jnp"))
    s_krn = np.asarray(score_batch(didx, qp, backend="pallas"))
    s_np = np.asarray(score_batch(didx, qp, backend="numpy"))
    np.testing.assert_allclose(s_krn, s_jnp, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s_np, s_jnp, rtol=1e-5, atol=1e-5)
    # deprecated spelling still routes: impl="kernel" → backend="pallas"
    s_old = np.asarray(score_batch(didx, qp, impl="kernel"))
    np.testing.assert_allclose(s_old, s_krn, rtol=0, atol=0)


def test_distributed_topk_matches_numpy():
    rng = np.random.default_rng(0)
    scores = jnp.asarray(rng.normal(size=(128, 5)), jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    v, i = distributed_topk(scores, 7, mesh)
    ref = np.sort(np.asarray(scores), axis=0)[::-1][:7]       # [7, 5]
    np.testing.assert_allclose(np.asarray(v), ref.T, rtol=1e-6)
    # ids point at the right values
    picked = np.take_along_axis(np.asarray(scores), np.asarray(i).T, axis=0)
    np.testing.assert_allclose(picked.T, np.asarray(v), rtol=1e-6)


def test_histogram_tau_near_exact():
    rng = np.random.default_rng(1)
    h = rng.integers(0, 2**32, size=20000).astype(np.uint32)
    budget = 1500
    exact = np.partition(h, budget - 1)[budget - 1]
    t = int(histogram_tau(jnp.asarray(h), budget))
    assert abs(int(exact) - t) <= (1 << 8)
    kept = int((h <= t).sum())
    assert budget <= kept <= budget + 16    # never under-covers the budget


def test_distributed_tau_matches_single_device():
    rng = np.random.default_rng(2)
    h = rng.integers(0, 2**32, size=8192).astype(np.uint32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    t1 = int(histogram_tau(jnp.asarray(h), 600))
    t2 = int(distributed_tau(jnp.asarray(h), 600, mesh, ("data",)))
    assert t1 == t2


def test_search_threshold_agrees_with_host_search():
    recs, idx = _setup(m=100, budget=6000, r=32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    didx = to_device_index(idx, mesh)
    q = recs[7]
    qp = batch_queries(idx, [q])
    from repro.sketchindex import distributed_search
    mask, scores = distributed_search(didx, qp, threshold=0.5)
    got = set(np.nonzero(np.asarray(mask)[: idx.num_records, 0])[0].tolist())
    host = set(gbkmv_mod.search(idx, q, 0.5).tolist())
    assert got == host


def test_padding_rows_never_match():
    recs, idx = _setup(m=37)          # odd size → padding on any mesh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    didx = to_device_index(idx, mesh)
    qp = batch_queries(idx, [recs[0]])
    scores = np.asarray(score_batch(didx, qp))
    assert (scores[idx.num_records:] == 0).all()
