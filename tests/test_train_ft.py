"""Training substrate + fault-tolerance tests: optimizer math, microbatch
equivalence, checkpoint roundtrip/reshard, elastic planning, straggler
detection, gradient compression.
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.ft import checkpoint as ckpt
from repro.ft.elastic import plan_remesh
from repro.ft.straggler import StragglerMonitor
from repro.parallel.compression import compressed_psum_mean, init_error_state
from repro.train import optim, steps


def _quad_loss(params, batch):
    """Convex toy problem: params should converge toward batch targets."""
    err = params["w"] - batch["target"]
    return jnp.mean(jnp.square(err)), {}


def test_adamw_converges():
    params = {"w": jnp.zeros((4, 4))}
    ocfg = optim.OptConfig(lr=0.05, warmup_steps=5, total_steps=200,
                           weight_decay=0.0)
    opt = optim.init(params, ocfg)
    step = jax.jit(steps.make_train_step(_quad_loss, ocfg))
    batch = {"target": jnp.full((4, 4), 3.0)}
    for _ in range(200):
        params, opt, met = step(params, opt, batch)
    assert float(jnp.abs(params["w"] - 3.0).max()) < 0.1


def test_microbatch_equivalence():
    """k-microbatch accumulation == single batch for the first step."""
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}
    batch = {"target": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}

    def loss(p, b):
        return jnp.mean(jnp.square(p["w"][None, :] - b["target"])), {}

    ocfg = optim.OptConfig(lr=1e-2, warmup_steps=0, grad_clip=0.0,
                           weight_decay=0.0)
    p1, _, m1 = steps.make_train_step(loss, ocfg)(params, optim.init(params, ocfg), batch)
    p4, _, m4 = steps.make_train_step(loss, ocfg, microbatches=4)(
        params, optim.init(params, ocfg), batch)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-5)


def test_schedule_warmup_cosine():
    ocfg = optim.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(optim.schedule(jnp.int32(s), ocfg)) for s in (0, 5, 10, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1e-6


def test_checkpoint_roundtrip_and_reshard(tmp_path):
    state = {"params": {"w": jnp.arange(16, dtype=jnp.bfloat16).reshape(4, 4)},
             "step": jnp.int32(7)}
    d = str(tmp_path / "ckpt")
    ckpt.save_checkpoint(d, 7, state, extra={"data_seed": 123})
    assert ckpt.latest_step(d) == 7

    mesh = jax.make_mesh((1,), ("data",))
    shardings = {"params": {"w": jax.NamedSharding(mesh, P("data", None))},
                 "step": jax.NamedSharding(mesh, P())}
    restored, manifest = ckpt.restore_checkpoint(
        d, target=state, shardings=shardings)
    assert manifest["extra"]["data_seed"] == 123
    assert restored["params"]["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"], np.float32),
        np.asarray(state["params"]["w"], np.float32))
    assert restored["params"]["w"].sharding.spec == P("data", None)


def test_checkpoint_atomicity(tmp_path):
    """A crashed (partial) save directory is never picked up."""
    d = str(tmp_path / "ckpt")
    ckpt.save_checkpoint(d, 1, {"x": jnp.zeros(2)})
    os.makedirs(os.path.join(d, "step_00000002.tmp"))  # simulated crash
    assert ckpt.latest_step(d) == 1


def test_elastic_plan_keeps_global_batch():
    mesh = jax.make_mesh((1,), ("data",))
    plan = plan_remesh(mesh, global_batch=256, per_device_batch=8)
    assert plan.dp_size * plan.per_device_batch * plan.microbatches == 256


def test_straggler_monitor():
    mon = StragglerMonitor(warmup=5, sustain_steps=3)
    for _ in range(20):
        assert mon.record(1.0) == "ok"
    assert mon.record(10.0) == "spike"
    assert mon.record(10.0) == "spike"
    status = mon.record(10.0)
    assert status == "sustained"
    assert mon.action(status) == "evict-and-remesh"
    # Recovery resets the streak.
    mon.record(1.0)
    assert mon.consecutive == 0


def test_compressed_psum_error_feedback():
    """int8 EF-compression: the residual is carried, and repeated steps on a
    constant gradient average out the quantization error."""
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(64,)),
                          jnp.float32) * 1e-3}
    e = init_error_state(g)
    from repro import compat
    fn = jax.jit(compat.shard_map(
        lambda gg, ee: compressed_psum_mean(gg, ee, "data"),
        mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P())))
    total = jnp.zeros_like(g["w"])
    for _ in range(32):
        out, e = fn(g, e)
        total = total + out["w"]
    # Mean of compressed outputs ≈ true gradient (error feedback property).
    np.testing.assert_allclose(np.asarray(total / 32), np.asarray(g["w"]),
                               atol=5e-6)
