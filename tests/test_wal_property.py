"""Property tests for the WAL frame codec: encode→decode is lossless
over arbitrary entries, a cut at any byte offset recovers exactly the
complete-frame prefix (the torn-tail contract recovery relies on), and
a flipped byte never yields a different entry — the scan stops instead.

Separate module: hypothesis is an optional dependency locally (CI
installs it), so the whole file importorskips."""

import pytest

pytest.importorskip("hypothesis", reason="property test needs hypothesis")

from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.service.wal import decode_segment, encode_entry  # noqa: E402

# Entries shaped like real WAL payloads: JSON-safe scalars and nested
# id lists, including empty records and unicode idempotency keys.
_ids = st.lists(st.integers(min_value=0, max_value=2**53 - 1), max_size=8)
_entry = st.fixed_dictionaries(
    {"seq": st.integers(min_value=1, max_value=2**31)},
    optional={
        "kind": st.sampled_from(["ingest", "retire"]),
        "records": st.lists(_ids, max_size=4),
        "epoch": st.none() | st.integers(min_value=0, max_value=1000),
        "idem": st.none() | st.text(max_size=20),
        "before": st.integers(min_value=0, max_value=2**31),
    })
_entries = st.lists(_entry, max_size=10)


@settings(max_examples=200, deadline=None)
@given(_entries)
def test_encode_decode_round_trip(entries):
    buf = b"".join(encode_entry(e) for e in entries)
    decoded, dropped = decode_segment(buf)
    assert decoded == entries
    assert dropped == 0


@settings(max_examples=200, deadline=None)
@given(_entries, st.data())
def test_cut_anywhere_recovers_complete_prefix(entries, data):
    frames = [encode_entry(e) for e in entries]
    buf = b"".join(frames)
    cut = data.draw(st.integers(min_value=0, max_value=len(buf)),
                    label="cut")
    decoded, dropped = decode_segment(buf[:cut])
    # Exactly the frames that fit wholly before the cut survive; the
    # torn remainder is accounted byte-for-byte, never silently eaten.
    keep, off = 0, 0
    for f in frames:
        if off + len(f) > cut:
            break
        off += len(f)
        keep += 1
    assert decoded == entries[:keep]
    assert dropped == cut - off


@settings(max_examples=200, deadline=None)
@given(_entries, st.data())
def test_flipped_byte_stops_scan_before_that_frame(entries, data):
    frames = [encode_entry(e) for e in entries]
    buf = bytearray(b"".join(frames))
    if not buf:
        return
    pos = data.draw(st.integers(min_value=0, max_value=len(buf) - 1),
                    label="pos")
    buf[pos] ^= 0xFF
    decoded, dropped = decode_segment(bytes(buf))
    # Find which frame the flipped byte lives in: every frame before it
    # must decode intact, and nothing at/after it may decode (a CRC or
    # header hit stops the scan — it never resynchronizes mid-garbage).
    off = victim = 0
    for i, f in enumerate(frames):
        if off <= pos < off + len(f):
            victim = i
            break
        off += len(f)
    assert decoded == entries[:victim]
    assert dropped == len(buf) - sum(len(f) for f in frames[:victim])
