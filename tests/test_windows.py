"""WindowManager: sliding-window queries over mergeable epoch arenas.

Contracts under test (sketchindex/windows.py, api ``windowed=True``):
windowed answers equal a one-shot index over the window's records
(merge bit-identity surfaced at the api level), epoch lifecycle is
append-only, retirement drops epochs and invalidates cached merged
views, serve_batch matches direct query/topk, and the snapshot
directory round-trips.
"""

import numpy as np
import pytest

from repro import api
from repro.core import gbkmv
from repro.sketchindex import WindowManager

BACKEND = "numpy"


def _records(rng, n, universe=2500, lo=4, hi=40):
    return [rng.choice(universe, size=int(rng.integers(lo, hi)),
                       replace=False) for _ in range(n)]


@pytest.fixture
def corpus():
    rng = np.random.default_rng(42)
    return _records(rng, 60), rng


def _windowed(engine, recs, budget, **cfg):
    wm = api.get_engine(engine).build(recs[:20], budget, backend=BACKEND,
                                      windowed=True, epoch=0, **cfg)
    wm.ingest(recs[20:40], epoch=1)
    wm.ingest(recs[40:], epoch=2)
    return wm


def test_windowed_build_returns_manager(corpus):
    recs, _ = corpus
    wm = api.get_engine("gbkmv").build(recs, 1500, backend=BACKEND,
                                       windowed=True)
    assert isinstance(wm, WindowManager)
    assert wm.windowed is True          # the serving feature-detect flag
    assert wm.epochs == [0] and wm.num_records == len(recs)


@pytest.mark.parametrize("engine", ["gkmv", "kmv"])
def test_windowed_equals_one_shot(corpus, engine):
    """Full-window answers == an index built over all records in one
    shot (gkmv/kmv merge identity needs only the shared budget)."""
    recs, rng = corpus
    budget = 6 * len(recs)
    wm = _windowed(engine, recs, budget)
    flat = api.get_engine(engine).build(recs, budget, backend=BACKEND)
    queries = [recs[5], recs[30], recs[55],
               rng.choice(2500, size=10, replace=False)]
    for t in (0.3, 0.6):
        for hw, hf in zip(wm.batch_query(queries, t),
                          flat.batch_query(queries, t)):
            assert np.array_equal(hw, hf)
    for q in queries:
        iw, sw = wm.topk(q, 7)
        if_, sf = flat.topk(q, 7)
        assert np.array_equal(iw, if_) and np.array_equal(sw, sf)


def test_windowed_gbkmv_equals_pinned_rebuild(corpus):
    """GB-KMV identity: epochs pin epoch 0's buffer set, so the merged
    window equals a one-shot build with top_elems pinned the same way
    (budget above the m*(ceil(r/32)+1) tail floor)."""
    recs, _ = corpus
    budget = 4 * len(recs)
    wm = _windowed("gbkmv", recs, budget, r=32)
    top = wm._frozen_top
    flat = api.GBKMVEngine.wrap(
        gbkmv.build_gbkmv(recs, budget, r=32, top_elems=top),
        budget=budget, backend=BACKEND)
    merged = wm.index()                 # the cached merged view
    assert np.array_equal(np.asarray(merged.core.sketches.values),
                          np.asarray(flat.core.sketches.values))
    assert int(merged.core.tau) == int(flat.core.tau)
    for q in (recs[3], recs[45]):
        assert np.array_equal(wm.query(q, 0.5), flat.query(q, 0.5))


def test_window_bounds_select_epochs(corpus):
    recs, _ = corpus
    wm = _windowed("gkmv", recs, 360)
    solo = api.get_engine("gkmv").build(recs[20:40], 360, backend=BACKEND)
    q = recs[25]
    # ids inside window (1, 1) are epoch-relative row numbers
    assert np.array_equal(wm.query(q, 0.4, window=(1, 1)),
                          solo.query(q, 0.4))
    with pytest.raises(ValueError, match="no live epochs"):
        wm.query(q, 0.4, window=(7, 9))


def test_epochs_are_append_only(corpus):
    recs, _ = corpus
    wm = _windowed("gbkmv", recs[:50], 1200)
    with pytest.raises(ValueError, match="sealed"):
        wm.ingest(recs[50:], epoch=1)   # current epoch is 2
    before = wm.num_records
    wm.ingest(recs[50:], epoch=2)       # open epoch extends in place
    assert wm.num_records == before + 10 and wm.epochs == [0, 1, 2]


def test_retire_drops_epochs_and_caches(corpus):
    recs, _ = corpus
    wm = _windowed("gkmv", recs, 360)
    _ = wm.query(recs[5], 0.4)          # builds + caches the 3-epoch view
    assert wm.window_stats()["cached_windows"] == 1
    merges_before = wm.merges_total
    assert wm.retire(before=1) == 1
    assert wm.epochs == [1, 2]
    assert wm.window_stats()["cached_windows"] == 0     # invalidated
    stats = wm.window_stats()
    assert stats["retired_epochs_total"] == 1
    assert stats["retired_records_total"] == 20
    # the surviving window answers like a fresh 2-epoch union
    hits = wm.query(recs[25], 0.4)
    assert wm.merges_total == merges_before + 1
    flat = api.get_engine("gkmv").build(recs[20:], 360, backend=BACKEND)
    assert np.array_equal(hits, flat.query(recs[25], 0.4))
    assert wm.retire(before=10) == 2
    with pytest.raises(ValueError, match="no live epochs"):
        wm.query(recs[5], 0.4)


def test_ingest_invalidates_cached_views(corpus):
    recs, rng = corpus
    wm = _windowed("gkmv", recs[:50], 300)
    q = recs[10]
    _ = wm.query(q, 0.4)
    assert wm.window_stats()["cached_windows"] == 1
    wm.ingest(recs[50:], epoch=2)       # extend the open epoch
    assert wm.window_stats()["cached_windows"] == 0
    flat = api.get_engine("gkmv").build(recs, 300, backend=BACKEND)
    assert np.array_equal(wm.query(q, 0.4), flat.query(q, 0.4))


def test_serve_batch_matches_direct(corpus):
    recs, rng = corpus
    wm = _windowed("gbkmv", recs, 1500)
    queries = [recs[2], recs[33], rng.choice(2500, size=8, replace=False)]
    out = wm.serve_batch(queries, [0.5, 0.3, 0.5], k=4)
    for q, t, res in zip(queries, [0.5, 0.3, 0.5], out):
        assert np.array_equal(res["hits"], wm.query(q, t))
        ids, scores = wm.topk(q, 4)
        assert np.array_equal(res["topk_ids"], ids)
        assert np.array_equal(res["topk_scores"], scores)


def test_save_load_roundtrip(corpus, tmp_path):
    recs, rng = corpus
    wm = _windowed("gbkmv", recs, 1500)
    wm.retire(before=1)
    d = tmp_path / "snaps"
    wm.save(str(d))
    back = WindowManager.load(str(d))
    assert back.engine == "gbkmv" and back.budget == wm.budget
    assert back.epochs == wm.epochs
    assert back.num_records == wm.num_records
    assert back.retired_epochs_total == 1
    assert np.array_equal(back._frozen_top, wm._frozen_top)
    for q in (recs[25], recs[50], rng.choice(2500, size=9, replace=False)):
        assert np.array_equal(back.query(q, 0.5), wm.query(q, 0.5))
        bi, bs = back.topk(q, 5)
        wi, ws = wm.topk(q, 5)
        assert np.array_equal(bi, wi) and np.array_equal(bs, ws)
    # gbkmv's newest epoch re-opens: dynamic insert keeps answering
    back.ingest(_records(rng, 5), epoch=2)
    assert back.num_records == wm.num_records + 5


def test_windowed_kwarg_rejected_for_unbudgeted_engines():
    with pytest.raises(ValueError, match="windowed"):
        WindowManager(engine="exact")


def test_nbytes_counts_snapshots_and_views(corpus):
    recs, _ = corpus
    wm = _windowed("gkmv", recs, 360)
    base = wm.nbytes()
    assert base == sum(s.nbytes() for s in wm._snaps.values())
    _ = wm.query(recs[0], 0.4)          # materializes a merged view
    assert wm.nbytes() > base
