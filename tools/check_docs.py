#!/usr/bin/env python
"""Docs gate for CI: link resolution + executable snippets.

Two checks, both designed to catch documentation drift the moment it
happens rather than when a reader trips over it:

1. **Link lint** — every relative markdown link in `*.md` (repo root
   and `docs/`) must resolve to a file or directory in the repo.
   External (`http(s)://`, `mailto:`) and intra-page (`#...`) targets
   are skipped; `path#anchor` checks only the path.
2. **Snippet execution** — the fenced ``python`` blocks in the sections
   listed in ``SNIPPET_TARGETS`` are executed top to bottom in a fresh
   namespace (numpy backend only — the CI job runs on plain CPU). A
   snippet that raises, including a failed ``assert``, fails the job,
   so the quickstarts cannot rot.

Run locally:

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import pathlib
import re
import sys
import traceback

ROOT = pathlib.Path(__file__).resolve().parent.parent

#: (markdown file, header prefix) sections whose ``python`` fences run.
SNIPPET_TARGETS = [
    ("docs/API.md", "## Construction"),
    ("docs/ARCHITECTURE.md", "## Quickstart"),
]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_EXTERNAL = ("http://", "https://", "mailto:")


def _strip_code(text: str) -> str:
    """Drop fenced code blocks so links inside snippets aren't linted."""
    return re.sub(r"```.*?```", "", text, flags=re.S)


def check_links(md_files: list[pathlib.Path]) -> list[str]:
    errors = []
    for md in md_files:
        for target in _LINK.findall(_strip_code(md.read_text())):
            if target.startswith(_EXTERNAL) or target.startswith("#"):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: broken link "
                              f"-> {target}")
    return errors


def _section(text: str, header_prefix: str) -> str:
    """The lines from the first header matching ``header_prefix`` up to
    the next header of the same or higher level."""
    lines = text.splitlines()
    start = next((i for i, ln in enumerate(lines)
                  if ln.startswith(header_prefix)), None)
    if start is None:
        raise KeyError(header_prefix)
    level = len(lines[start]) - len(lines[start].lstrip("#"))
    fenced = False   # '#' inside a code fence is a comment, not a header
    for end in range(start + 1, len(lines)):
        ln = lines[end]
        if ln.startswith("```"):
            fenced = not fenced
        if (not fenced and ln.startswith("#")
                and (len(ln) - len(ln.lstrip("#"))) <= level):
            return "\n".join(lines[start:end])
    return "\n".join(lines[start:])


_FENCE = re.compile(r"```python\n(.*?)```", re.S)


def run_snippets() -> list[str]:
    errors = []
    for rel, header in SNIPPET_TARGETS:
        md = ROOT / rel
        try:
            section = _section(md.read_text(), header)
        except KeyError:
            errors.append(f"{rel}: section {header!r} not found "
                          "(SNIPPET_TARGETS is stale)")
            continue
        blocks = _FENCE.findall(section)
        if not blocks:
            errors.append(f"{rel} {header!r}: no fenced python snippet")
        for i, code in enumerate(blocks):
            print(f"running {rel} {header!r} snippet {i + 1}/{len(blocks)}"
                  f" ({len(code.splitlines())} lines)")
            try:
                exec(compile(code, f"{rel}#{header}", "exec"),
                     {"__name__": "__docsnippet__"})
            except Exception:
                errors.append(f"{rel} {header!r} snippet {i + 1} raised:\n"
                              f"{traceback.format_exc()}")
    return errors


def main() -> int:
    md_files = sorted(ROOT.glob("*.md")) + sorted((ROOT / "docs").glob("*.md"))
    errors = check_links(md_files)
    print(f"link lint: {len(md_files)} files, {len(errors)} broken")
    errors += run_snippets()
    if errors:
        print("\n".join(["", "DOCS CHECK FAILED:"] + errors))
        return 1
    print("docs check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
